//! Parameter tensors: llm.c's ParameterTensors, one flat arena.
//!
//! Order and shapes are the ABI shared with the JAX artifacts (see
//! python/compile/model.py PARAM_NAMES). Weight matrices are stored the
//! way llm.c stores them — (OC, IC) row-major, i.e. **column-major from
//! the GEMM's point of view** — which is precisely why the paper's engine
//! must transpose on copy.

use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::config::ModelConfig;

/// Parameter names in ABI order.
pub const PARAM_NAMES: [&str; 16] = [
    "wte", "wpe", "ln1w", "ln1b", "qkvw", "qkvb", "attprojw", "attprojb",
    "ln2w", "ln2b", "fcw", "fcb", "fcprojw", "fcprojb", "lnfw", "lnfb",
];

/// Shapes of all 16 tensors for a config, in ABI order.
pub fn param_shapes(cfg: &ModelConfig) -> Vec<(&'static str, Vec<usize>)> {
    let (c, l, t, vp) = (
        cfg.channels,
        cfg.num_layers,
        cfg.max_seq_len,
        cfg.padded_vocab_size,
    );
    vec![
        ("wte", vec![vp, c]),
        ("wpe", vec![t, c]),
        ("ln1w", vec![l, c]),
        ("ln1b", vec![l, c]),
        ("qkvw", vec![l, 3 * c, c]),
        ("qkvb", vec![l, 3 * c]),
        ("attprojw", vec![l, c, c]),
        ("attprojb", vec![l, c]),
        ("ln2w", vec![l, c]),
        ("ln2b", vec![l, c]),
        ("fcw", vec![l, 4 * c, c]),
        ("fcb", vec![l, 4 * c]),
        ("fcprojw", vec![l, c, 4 * c]),
        ("fcprojb", vec![l, c]),
        ("lnfw", vec![c]),
        ("lnfb", vec![c]),
    ]
}

/// A flat parameter arena with named views (used for params, grads, and
/// the two AdamW moment buffers alike).
#[derive(Debug, Clone)]
pub struct ParamTensors {
    cfg: ModelConfig,
    data: Vec<f32>,
    /// (name, offset, len) per tensor, ABI order.
    index: Vec<(&'static str, usize, usize)>,
}

impl ParamTensors {
    /// Zero-initialized arena.
    pub fn zeros(cfg: &ModelConfig) -> ParamTensors {
        let mut index = Vec::with_capacity(16);
        let mut off = 0usize;
        for (name, shape) in param_shapes(cfg) {
            let len: usize = shape.iter().product();
            index.push((name, off, len));
            off += len;
        }
        ParamTensors {
            cfg: *cfg,
            data: vec![0.0; off],
            index,
        }
    }

    /// GPT-2 initialization (llm.c / nanoGPT): std 0.02 normals, residual
    /// projections scaled 1/sqrt(2L), layernorm weights 1, biases 0.
    pub fn random_init(cfg: &ModelConfig, rng: &mut Rng) -> ParamTensors {
        let mut p = ParamTensors::zeros(cfg);
        let resid_scale = 1.0 / (2.0 * cfg.num_layers as f32).sqrt();
        for (name, off, len) in p.index.clone() {
            let slice = &mut p.data[off..off + len];
            match name {
                "ln1w" | "ln2w" | "lnfw" => slice.fill(1.0),
                n if n.ends_with('b') => slice.fill(0.0),
                "attprojw" | "fcprojw" => {
                    rng.fill_normal(slice, 0.0, 0.02 * resid_scale)
                }
                _ => rng.fill_normal(slice, 0.0, 0.02),
            }
        }
        p
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn num_parameters(&self) -> usize {
        self.data.len()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    fn entry(&self, name: &str) -> Result<(usize, usize)> {
        self.index
            .iter()
            .find(|(n, _, _)| *n == name)
            .map(|(_, o, l)| (*o, *l))
            .ok_or_else(|| Error::config(format!("unknown param tensor '{name}'")))
    }

    /// Whole tensor by name.
    pub fn tensor(&self, name: &str) -> &[f32] {
        let (o, l) = self.entry(name).expect("valid tensor name");
        &self.data[o..o + l]
    }

    pub fn tensor_mut(&mut self, name: &str) -> &mut [f32] {
        let (o, l) = self.entry(name).expect("valid tensor name");
        &mut self.data[o..o + l]
    }

    /// Layer `l`'s slice of a per-layer tensor (leading dim = num_layers).
    pub fn layer(&self, name: &str, l: usize) -> &[f32] {
        let t = self.tensor(name);
        let per = t.len() / self.cfg.num_layers;
        &t[l * per..(l + 1) * per]
    }

    pub fn layer_mut(&mut self, name: &str, l: usize) -> &mut [f32] {
        let layers = self.cfg.num_layers;
        let t = self.tensor_mut(name);
        let per = t.len() / layers;
        &mut t[l * per..(l + 1) * per]
    }

    /// Flat (offset, len) of a tensor — used to exchange with PJRT
    /// literals and checkpoints.
    pub fn tensor_range(&self, name: &str) -> Result<(usize, usize)> {
        self.entry(name)
    }

    /// Flat (offset, len) of layer `l`'s slice of a per-layer tensor —
    /// the arena coordinates the background executor's deferred dW jobs
    /// name instead of pointers.
    pub fn layer_range(&self, name: &str, l: usize) -> Result<(usize, usize)> {
        let (off, len) = self.entry(name)?;
        let per = len / self.cfg.num_layers;
        Ok((off + l * per, per))
    }

    /// Two simultaneous mutable tensor views (optionally layer-sliced).
    /// The backward pass needs (dweight, dbias) pairs at once; tensors are
    /// disjoint by construction, asserted here before the unsafe split.
    pub fn pair_mut(
        &mut self,
        name1: &str,
        layer1: Option<usize>,
        name2: &str,
        layer2: Option<usize>,
    ) -> (&mut [f32], &mut [f32]) {
        let slice_of = |this: &ParamTensors, name: &str, layer: Option<usize>| {
            let (off, len) = this.entry(name).expect("valid tensor name");
            match layer {
                None => (off, len),
                Some(l) => {
                    let per = len / this.cfg.num_layers;
                    (off + l * per, per)
                }
            }
        };
        let (o1, l1) = slice_of(self, name1, layer1);
        let (o2, l2) = slice_of(self, name2, layer2);
        assert!(
            o1 + l1 <= o2 || o2 + l2 <= o1,
            "pair_mut ranges overlap: {name1}/{name2}"
        );
        // SAFETY: ranges proven disjoint above.
        let ptr = self.data.as_mut_ptr();
        unsafe {
            (
                std::slice::from_raw_parts_mut(ptr.add(o1), l1),
                std::slice::from_raw_parts_mut(ptr.add(o2), l2),
            )
        }
    }

    /// Shapes in ABI order (for literal construction).
    pub fn shapes(&self) -> Vec<(&'static str, Vec<usize>)> {
        param_shapes(&self.cfg)
    }

    /// Whether two parameter sets are elementwise close.
    pub fn allclose(&self, other: &ParamTensors, rtol: f32, atol: f32) -> bool {
        self.data.len() == other.data.len()
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_124m_parameter_count() {
        // llm.c reports 124,475,904 padded params for GPT-2 small
        // (124M unpadded + vocab padding rows).
        let p = ParamTensors::zeros(&ModelConfig::d12());
        assert_eq!(p.num_parameters(), 124_475_904);
    }

    #[test]
    fn layer_views_are_disjoint_and_cover() {
        let cfg = ModelConfig::d2();
        let p = ParamTensors::zeros(&cfg);
        let full = p.tensor("qkvw").len();
        let per: usize = (0..cfg.num_layers).map(|l| p.layer("qkvw", l).len()).sum();
        assert_eq!(full, per);
    }

    #[test]
    fn layer_range_names_the_layer_view_in_arena_coordinates() {
        let cfg = ModelConfig::d2();
        let p = ParamTensors::zeros(&cfg);
        for l in 0..cfg.num_layers {
            let (off, len) = p.layer_range("fcprojw", l).unwrap();
            assert_eq!(len, p.layer("fcprojw", l).len());
            let (t_off, _) = p.tensor_range("fcprojw").unwrap();
            assert_eq!(off, t_off + l * len);
        }
        assert!(p.layer_range("nope", 0).is_err());
    }

    #[test]
    fn init_statistics() {
        let cfg = ModelConfig::d4();
        let mut rng = Rng::new(1);
        let p = ParamTensors::random_init(&cfg, &mut rng);
        // layernorm weights exactly 1, biases 0.
        assert!(p.tensor("ln1w").iter().all(|&x| x == 1.0));
        assert!(p.tensor("qkvb").iter().all(|&x| x == 0.0));
        // wte roughly std 0.02.
        let wte = p.tensor("wte");
        let var: f32 = wte.iter().map(|x| x * x).sum::<f32>() / wte.len() as f32;
        assert!((var.sqrt() - 0.02).abs() < 0.002, "std {}", var.sqrt());
        // residual projections scaled down.
        let ap = p.tensor("attprojw");
        let var2: f32 = ap.iter().map(|x| x * x).sum::<f32>() / ap.len() as f32;
        assert!(var2.sqrt() < 0.02);
    }

    #[test]
    fn unknown_tensor_errors() {
        let p = ParamTensors::zeros(&ModelConfig::d2());
        assert!(p.tensor_range("nope").is_err());
    }
}
