//! llm.c, ported: GPT-2 forward/backward/AdamW in pure Rust.
//!
//! The paper modifies Karpathy's llm.c — a framework-free C implementation
//! of GPT-2 training — to dispatch its matmuls to the NPU. This module is
//! that application, ported 1:1: the same 16-tensor parameter inventory
//! (column-major weights!), the same activation arenas, the same op
//! sequence, and a matmul seam ([`matmul::MatmulDispatch`]) that either
//! runs the llm.c CPU loop nest or calls the offload engine.
//!
//! Numerics are cross-checked three ways in tests: against finite
//! differences, against the JAX train-step artifact through PJRT, and
//! between CPU and NPU dispatch.

pub mod acts;
pub mod config;
pub mod data;
pub mod flops;
pub mod generate;
pub mod kv_cache;
pub mod model;
pub mod ops;
pub mod params;
pub mod trainer;

pub use config::ModelConfig;
pub use generate::{serve, AdmissionPolicy, GenRequest, Generation, ServeConfig, ServeReport};
pub use kv_cache::{KvCache, KvCacheMode};
pub use model::{Gpt2Model, OpTimers};
pub use params::{ParamTensors, PARAM_NAMES};
