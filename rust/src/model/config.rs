//! Model hyperparameters (mirrors llm.c's GPT2Config and the Python
//! `GPT2Config`; the named presets match `python/compile/model.py`).

use crate::runtime::manifest::ModelArtifact;
use crate::util::error::{Error, Result};

/// GPT-2 configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub max_seq_len: usize,
    pub vocab_size: usize,
    /// llm.c pads the vocab to a multiple of 128 for nicer GEMMs.
    pub padded_vocab_size: usize,
    pub num_layers: usize,
    pub num_heads: usize,
    pub channels: usize,
}

impl ModelConfig {
    /// GPT-2 small — the paper's 124M model.
    pub const fn d12() -> ModelConfig {
        ModelConfig {
            max_seq_len: 1024,
            vocab_size: 50257,
            padded_vocab_size: 50304,
            num_layers: 12,
            num_heads: 12,
            channels: 768,
        }
    }

    /// Tiny test config (matches python CONFIGS["d2"]).
    pub const fn d2() -> ModelConfig {
        ModelConfig {
            max_seq_len: 32,
            vocab_size: 256,
            padded_vocab_size: 256,
            num_layers: 2,
            num_heads: 2,
            channels: 64,
        }
    }

    /// Small config (python CONFIGS["d4"]).
    pub const fn d4() -> ModelConfig {
        ModelConfig {
            max_seq_len: 64,
            vocab_size: 512,
            padded_vocab_size: 512,
            num_layers: 4,
            num_heads: 4,
            channels: 128,
        }
    }

    /// Medium config (python CONFIGS["d6"], ~13M params).
    pub const fn d6() -> ModelConfig {
        ModelConfig {
            max_seq_len: 128,
            vocab_size: 2048,
            padded_vocab_size: 2048,
            num_layers: 6,
            num_heads: 6,
            channels: 384,
        }
    }

    /// Look up a named preset.
    pub fn by_name(name: &str) -> Result<ModelConfig> {
        match name {
            "d2" => Ok(Self::d2()),
            "d4" => Ok(Self::d4()),
            "d6" => Ok(Self::d6()),
            "d12" | "gpt2" | "gpt2-124m" => Ok(Self::d12()),
            other => Err(Error::config(format!("unknown model config '{other}'"))),
        }
    }

    /// Build from a manifest model artifact (must agree with the preset
    /// the artifact was lowered for).
    pub fn from_artifact(a: &ModelArtifact) -> ModelConfig {
        ModelConfig {
            max_seq_len: a.max_seq_len,
            vocab_size: a.vocab_size,
            padded_vocab_size: a.padded_vocab_size,
            num_layers: a.num_layers,
            num_heads: a.num_heads,
            channels: a.channels,
        }
    }

    pub fn head_size(&self) -> usize {
        self.channels / self.num_heads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        for cfg in [ModelConfig::d2(), ModelConfig::d4(), ModelConfig::d6(), ModelConfig::d12()] {
            assert_eq!(cfg.channels % cfg.num_heads, 0);
            assert!(cfg.padded_vocab_size >= cfg.vocab_size);
            assert_eq!(cfg.padded_vocab_size % 128, 0);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(ModelConfig::by_name("d12").unwrap(), ModelConfig::d12());
        assert!(ModelConfig::by_name("bogus").is_err());
    }
}
