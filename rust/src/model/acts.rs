//! Activation arenas (llm.c's ActivationTensors), for one (B, T) shape.
//!
//! llm.c preallocates every intermediate once and reuses it each step; we
//! keep the same inventory so the backward pass can consume cached values
//! (layernorm mean/rstd, attention probabilities, pre-GELU activations).

use super::config::ModelConfig;

/// All forward intermediates for a batch.
#[derive(Debug, Clone)]
pub struct Activations {
    pub b: usize,
    pub t: usize,
    /// (B,T,C) token+position embeddings.
    pub encoded: Vec<f32>,
    /// Per layer (L,B,T,C).
    pub ln1: Vec<f32>,
    pub ln1_mean: Vec<f32>,
    pub ln1_rstd: Vec<f32>,
    /// (L,B,T,3C)
    pub qkv: Vec<f32>,
    /// (L,B,T,C)
    pub atty: Vec<f32>,
    /// (L,B,NH,T,T)
    pub preatt: Vec<f32>,
    pub att: Vec<f32>,
    /// (L,B,T,C)
    pub attproj: Vec<f32>,
    pub residual2: Vec<f32>,
    pub ln2: Vec<f32>,
    pub ln2_mean: Vec<f32>,
    pub ln2_rstd: Vec<f32>,
    /// (L,B,T,4C)
    pub fch: Vec<f32>,
    pub fch_gelu: Vec<f32>,
    /// (L,B,T,C)
    pub fcproj: Vec<f32>,
    pub residual3: Vec<f32>,
    /// (B,T,C)
    pub lnf: Vec<f32>,
    pub lnf_mean: Vec<f32>,
    pub lnf_rstd: Vec<f32>,
    /// (B,T,Vp)
    pub logits: Vec<f32>,
    pub probs: Vec<f32>,
    /// (B,T)
    pub losses: Vec<f32>,
}

impl Activations {
    pub fn new(cfg: &ModelConfig, b: usize, t: usize) -> Activations {
        let c = cfg.channels;
        let l = cfg.num_layers;
        let nh = cfg.num_heads;
        let vp = cfg.padded_vocab_size;
        let bt = b * t;
        Activations {
            b,
            t,
            encoded: vec![0.0; bt * c],
            ln1: vec![0.0; l * bt * c],
            ln1_mean: vec![0.0; l * bt],
            ln1_rstd: vec![0.0; l * bt],
            qkv: vec![0.0; l * bt * 3 * c],
            atty: vec![0.0; l * bt * c],
            preatt: vec![0.0; l * b * nh * t * t],
            att: vec![0.0; l * b * nh * t * t],
            attproj: vec![0.0; l * bt * c],
            residual2: vec![0.0; l * bt * c],
            ln2: vec![0.0; l * bt * c],
            ln2_mean: vec![0.0; l * bt],
            ln2_rstd: vec![0.0; l * bt],
            fch: vec![0.0; l * bt * 4 * c],
            fch_gelu: vec![0.0; l * bt * 4 * c],
            fcproj: vec![0.0; l * bt * c],
            residual3: vec![0.0; l * bt * c],
            lnf: vec![0.0; bt * c],
            lnf_mean: vec![0.0; bt],
            lnf_rstd: vec![0.0; bt],
            logits: vec![0.0; bt * vp],
            probs: vec![0.0; bt * vp],
            losses: vec![0.0; bt],
        }
    }

    /// Total f32 elements (llm.c prints this at startup).
    pub fn num_activations(&self) -> usize {
        self.encoded.len()
            + self.ln1.len()
            + self.ln1_mean.len()
            + self.ln1_rstd.len()
            + self.qkv.len()
            + self.atty.len()
            + self.preatt.len()
            + self.att.len()
            + self.attproj.len()
            + self.residual2.len()
            + self.ln2.len()
            + self.ln2_mean.len()
            + self.ln2_rstd.len()
            + self.fch.len()
            + self.fch_gelu.len()
            + self.fcproj.len()
            + self.residual3.len()
            + self.lnf.len()
            + self.lnf_mean.len()
            + self.lnf_rstd.len()
            + self.logits.len()
            + self.probs.len()
            + self.losses.len()
    }

    /// Mean loss over all positions (valid after a forward with targets).
    pub fn mean_loss(&self) -> f32 {
        self.losses.iter().sum::<f32>() / self.losses.len() as f32
    }
}

/// Gradient arenas for the subset of activations the backward pass needs
/// scratch space for (llm.c reuses a mirror arena; we do the same).
///
/// The four `dout` scratches that feed a deferred backward weight
/// gradient (`d_qkv`, `d_attproj`, `d_fch`, `d_fcproj`) are *parity
/// pairs* indexed by `layer % 2`: the background executor borrows the
/// buffer zero-copy for the deferred `dW` job, and rotating two stable
/// buffers guarantees the borrow is retired (a later layer's in-call
/// `dinp` wait drains everything submitted before it, FIFO) before the
/// same physical buffer is rewritten two layers later. `d_logits` is
/// written once per step, so it is step-stable without rotation.
#[derive(Debug, Clone)]
pub struct ActGrads {
    /// (B,T,C)
    pub d_encoded: Vec<f32>,
    /// scratch per layer (B,T,C)
    pub d_ln1: Vec<f32>,
    /// parity-rotated (2,B,T,3C)
    pub d_qkv: [Vec<f32>; 2],
    pub d_atty: Vec<f32>,
    pub d_preatt: Vec<f32>,
    pub d_att: Vec<f32>,
    /// parity-rotated (2,B,T,C)
    pub d_attproj: [Vec<f32>; 2],
    pub d_residual2: Vec<f32>,
    pub d_ln2: Vec<f32>,
    /// parity-rotated (2,B,T,4C)
    pub d_fch: [Vec<f32>; 2],
    pub d_fch_gelu: Vec<f32>,
    /// parity-rotated (2,B,T,C)
    pub d_fcproj: [Vec<f32>; 2],
    pub d_residual3: Vec<f32>,
    pub d_lnf: Vec<f32>,
    pub d_logits: Vec<f32>,
}

impl ActGrads {
    pub fn new(cfg: &ModelConfig, b: usize, t: usize) -> ActGrads {
        let c = cfg.channels;
        let nh = cfg.num_heads;
        let vp = cfg.padded_vocab_size;
        let bt = b * t;
        ActGrads {
            d_encoded: vec![0.0; bt * c],
            d_ln1: vec![0.0; bt * c],
            d_qkv: [vec![0.0; bt * 3 * c], vec![0.0; bt * 3 * c]],
            d_atty: vec![0.0; bt * c],
            d_preatt: vec![0.0; b * nh * t * t],
            d_att: vec![0.0; b * nh * t * t],
            d_attproj: [vec![0.0; bt * c], vec![0.0; bt * c]],
            d_residual2: vec![0.0; bt * c],
            d_ln2: vec![0.0; bt * c],
            d_fch: [vec![0.0; bt * 4 * c], vec![0.0; bt * 4 * c]],
            d_fch_gelu: vec![0.0; bt * 4 * c],
            d_fcproj: [vec![0.0; bt * c], vec![0.0; bt * c]],
            d_residual3: vec![0.0; bt * c],
            d_lnf: vec![0.0; bt * c],
            d_logits: vec![0.0; bt * vp],
        }
    }

    pub fn zero(&mut self) {
        for v in [
            &mut self.d_encoded,
            &mut self.d_ln1,
            &mut self.d_atty,
            &mut self.d_preatt,
            &mut self.d_att,
            &mut self.d_residual2,
            &mut self.d_ln2,
            &mut self.d_fch_gelu,
            &mut self.d_residual3,
            &mut self.d_lnf,
            &mut self.d_logits,
        ] {
            v.fill(0.0);
        }
        for pair in [
            &mut self.d_qkv,
            &mut self.d_attproj,
            &mut self.d_fch,
            &mut self.d_fcproj,
        ] {
            for v in pair.iter_mut() {
                v.fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_scale_with_batch() {
        let cfg = ModelConfig::d2();
        let a1 = Activations::new(&cfg, 1, 8);
        let a2 = Activations::new(&cfg, 2, 8);
        assert_eq!(a2.encoded.len(), 2 * a1.encoded.len());
        assert!(a2.num_activations() > a1.num_activations());
    }

    #[test]
    fn grads_zero() {
        let cfg = ModelConfig::d2();
        let mut g = ActGrads::new(&cfg, 1, 4);
        g.d_qkv[0][0] = 5.0;
        g.d_qkv[1][0] = 5.0;
        g.zero();
        assert!(g.d_qkv.iter().all(|v| v.iter().all(|&x| x == 0.0)));
    }
}
