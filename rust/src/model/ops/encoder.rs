//! Token + position embedding (llm.c encoder_forward / encoder_backward).

/// out(B,T,C) = wte[tokens] + wpe[:T].
pub fn forward(
    out: &mut [f32],
    tokens: &[i32],
    wte: &[f32],
    wpe: &[f32],
    b: usize,
    t: usize,
    c: usize,
) {
    for bi in 0..b {
        for ti in 0..t {
            let ix = tokens[bi * t + ti] as usize;
            let out_row = &mut out[(bi * t + ti) * c..(bi * t + ti + 1) * c];
            let wte_row = &wte[ix * c..(ix + 1) * c];
            let wpe_row = &wpe[ti * c..(ti + 1) * c];
            for i in 0..c {
                out_row[i] = wte_row[i] + wpe_row[i];
            }
        }
    }
}

/// Accumulates into dwte / dwpe.
pub fn backward(
    dwte: &mut [f32],
    dwpe: &mut [f32],
    dout: &[f32],
    tokens: &[i32],
    b: usize,
    t: usize,
    c: usize,
) {
    for bi in 0..b {
        for ti in 0..t {
            let ix = tokens[bi * t + ti] as usize;
            let dout_row = &dout[(bi * t + ti) * c..(bi * t + ti + 1) * c];
            for i in 0..c {
                dwte[ix * c + i] += dout_row[i];
                dwpe[ti * c + i] += dout_row[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_picks_rows() {
        let (b, t, c) = (1, 2, 3);
        let wte = vec![0.0, 0.0, 0.0, 1.0, 2.0, 3.0]; // 2 tokens
        let wpe = vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0];
        let tokens = vec![1, 0];
        let mut out = vec![0.0; b * t * c];
        forward(&mut out, &tokens, &wte, &wpe, b, t, c);
        assert_eq!(out, vec![11.0, 22.0, 33.0, 40.0, 50.0, 60.0]);
    }

    #[test]
    fn backward_scatters_and_accumulates() {
        let (b, t, c) = (1, 2, 2);
        let tokens = vec![1, 1]; // same token twice: grads accumulate
        let dout = vec![1.0, 2.0, 3.0, 4.0];
        let mut dwte = vec![0.0; 2 * c];
        let mut dwpe = vec![0.0; t * c];
        backward(&mut dwte, &mut dwpe, &dout, &tokens, b, t, c);
        assert_eq!(dwte, vec![0.0, 0.0, 4.0, 6.0]);
        assert_eq!(dwpe, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
