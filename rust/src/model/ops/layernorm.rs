//! Layer normalization (llm.c layernorm_forward / layernorm_backward),
//! caching mean and rstd per row for the backward pass.

const EPS: f32 = 1e-5;

/// out(R,C) = norm(inp) * weight + bias; caches mean/rstd per row.
pub fn forward(
    out: &mut [f32],
    mean: &mut [f32],
    rstd: &mut [f32],
    inp: &[f32],
    weight: &[f32],
    bias: &[f32],
    rows: usize,
    c: usize,
) {
    for r in 0..rows {
        let x = &inp[r * c..(r + 1) * c];
        let m: f32 = x.iter().sum::<f32>() / c as f32;
        let v: f32 = x.iter().map(|&xi| (xi - m) * (xi - m)).sum::<f32>() / c as f32;
        let s = 1.0 / (v + EPS).sqrt();
        let o = &mut out[r * c..(r + 1) * c];
        for i in 0..c {
            o[i] = (x[i] - m) * s * weight[i] + bias[i];
        }
        mean[r] = m;
        rstd[r] = s;
    }
}

/// Accumulates dinp, dweight, dbias from dout using cached mean/rstd.
pub fn backward(
    dinp: &mut [f32],
    dweight: &mut [f32],
    dbias: &mut [f32],
    dout: &[f32],
    inp: &[f32],
    weight: &[f32],
    mean: &[f32],
    rstd: &[f32],
    rows: usize,
    c: usize,
) {
    for r in 0..rows {
        let x = &inp[r * c..(r + 1) * c];
        let dy = &dout[r * c..(r + 1) * c];
        let m = mean[r];
        let s = rstd[r];

        // Two reduction passes (llm.c's dnorm_mean / dnorm_norm_mean).
        let mut dnorm_mean = 0.0f32;
        let mut dnorm_norm_mean = 0.0f32;
        for i in 0..c {
            let norm = (x[i] - m) * s;
            let dnorm = weight[i] * dy[i];
            dnorm_mean += dnorm;
            dnorm_norm_mean += dnorm * norm;
        }
        dnorm_mean /= c as f32;
        dnorm_norm_mean /= c as f32;

        let di = &mut dinp[r * c..(r + 1) * c];
        for i in 0..c {
            let norm = (x[i] - m) * s;
            let dnorm = weight[i] * dy[i];
            dbias[i] += dy[i];
            dweight[i] += norm * dy[i];
            di[i] += (dnorm - dnorm_mean - norm * dnorm_norm_mean) * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn forward_normalizes() {
        let (rows, c) = (2, 8);
        let mut rng = Rng::new(3);
        let inp = prop::gen::normal_vec(&mut rng, rows * c);
        let weight = vec![1.0; c];
        let bias = vec![0.0; c];
        let mut out = vec![0.0; rows * c];
        let mut mean = vec![0.0; rows];
        let mut rstd = vec![0.0; rows];
        forward(&mut out, &mut mean, &mut rstd, &inp, &weight, &bias, rows, c);
        for r in 0..rows {
            let row = &out[r * c..(r + 1) * c];
            let m: f32 = row.iter().sum::<f32>() / c as f32;
            let v: f32 = row.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / c as f32;
            assert!(m.abs() < 1e-5);
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    /// Finite-difference check of the full backward.
    #[test]
    fn backward_matches_finite_differences() {
        let (rows, c) = (2, 6);
        let mut rng = Rng::new(5);
        let inp = prop::gen::normal_vec(&mut rng, rows * c);
        let weight = prop::gen::uniform_vec(&mut rng, c, 0.5, 1.5);
        let bias = prop::gen::normal_vec(&mut rng, c);
        let dout = prop::gen::normal_vec(&mut rng, rows * c);

        let loss = |inp: &[f32], weight: &[f32], bias: &[f32]| -> f32 {
            let mut out = vec![0.0; rows * c];
            let mut mean = vec![0.0; rows];
            let mut rstd = vec![0.0; rows];
            forward(&mut out, &mut mean, &mut rstd, inp, weight, bias, rows, c);
            out.iter().zip(&dout).map(|(o, d)| o * d).sum()
        };

        let mut out = vec![0.0; rows * c];
        let mut mean = vec![0.0; rows];
        let mut rstd = vec![0.0; rows];
        forward(&mut out, &mut mean, &mut rstd, &inp, &weight, &bias, rows, c);
        let mut dinp = vec![0.0; rows * c];
        let mut dweight = vec![0.0; c];
        let mut dbias = vec![0.0; c];
        backward(
            &mut dinp, &mut dweight, &mut dbias, &dout, &inp, &weight, &mean, &rstd, rows, c,
        );

        let h = 1e-3f32;
        for i in [0usize, 3, rows * c - 1] {
            let mut ip = inp.clone();
            ip[i] += h;
            let mut im = inp.clone();
            im[i] -= h;
            let fd = (loss(&ip, &weight, &bias) - loss(&im, &weight, &bias)) / (2.0 * h);
            assert!((fd - dinp[i]).abs() < 2e-2, "dinp[{i}]: fd {fd} vs {}", dinp[i]);
        }
        for i in [0usize, c - 1] {
            let mut wp = weight.clone();
            wp[i] += h;
            let mut wm = weight.clone();
            wm[i] -= h;
            let fd = (loss(&inp, &wp, &bias) - loss(&inp, &wm, &bias)) / (2.0 * h);
            assert!((fd - dweight[i]).abs() < 2e-2, "dweight[{i}]: fd {fd} vs {}", dweight[i]);
        }
    }
}
