//! Fused softmax + cross-entropy classifier (llm.c softmax_forward +
//! crossentropy_forward + crossentropy_softmax_backward).
//!
//! Logits over the padded vocab; positions past `vocab_size` are real
//! logits in llm.c too (they learn to be -inf-ish); targets are always
//! < vocab_size.

use crate::util::threads::parallel_for;

/// probs = softmax(logits) rowwise; losses[r] = -log(probs[target]).
pub fn forward(
    probs: &mut [f32],
    losses: &mut [f32],
    logits: &[f32],
    targets: &[i32],
    rows: usize,
    vp: usize,
) {
    let probs_addr = probs.as_mut_ptr() as usize;
    let losses_addr = losses.as_mut_ptr() as usize;
    let (plen, llen) = (probs.len(), losses.len());
    parallel_for(rows, 8, |range| {
        // SAFETY: disjoint rows.
        let probs = unsafe { std::slice::from_raw_parts_mut(probs_addr as *mut f32, plen) };
        let losses = unsafe { std::slice::from_raw_parts_mut(losses_addr as *mut f32, llen) };
        for r in range {
            let row = &logits[r * vp..(r + 1) * vp];
            let p = &mut probs[r * vp..(r + 1) * vp];
            let maxv = row.iter().copied().fold(f32::MIN, f32::max);
            let mut sum = 0.0f32;
            for i in 0..vp {
                let e = (row[i] - maxv).exp();
                p[i] = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for v in p.iter_mut() {
                *v *= inv;
            }
            let target = targets[r] as usize;
            losses[r] = -p[target].max(1e-30).ln();
        }
    });
}

/// dlogits += (probs - onehot(target)) * dloss, with dloss = 1/rows
/// (mean-loss convention, like llm.c's fused classifier).
pub fn backward(
    dlogits: &mut [f32],
    probs: &[f32],
    targets: &[i32],
    rows: usize,
    vp: usize,
) {
    let dloss = 1.0 / rows as f32;
    for r in 0..rows {
        let p = &probs[r * vp..(r + 1) * vp];
        let d = &mut dlogits[r * vp..(r + 1) * vp];
        let target = targets[r] as usize;
        for i in 0..vp {
            let indicator = if i == target { 1.0 } else { 0.0 };
            d[i] += (p[i] - indicator) * dloss;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_v_loss() {
        let (rows, vp) = (2, 16);
        let logits = vec![0.0f32; rows * vp];
        let targets = vec![3i32, 7];
        let mut probs = vec![0.0; rows * vp];
        let mut losses = vec![0.0; rows];
        forward(&mut probs, &mut losses, &logits, &targets, rows, vp);
        for &l in &losses {
            assert!((l - (vp as f32).ln()).abs() < 1e-5);
        }
        let sum: f32 = probs[..vp].iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (rows, vp) = (2, 8);
        let mut rng = crate::util::rng::Rng::new(91);
        let logits = crate::util::prop::gen::normal_vec(&mut rng, rows * vp);
        let targets = vec![1i32, 6];

        let loss = |logits: &[f32]| -> f32 {
            let mut probs = vec![0.0; rows * vp];
            let mut losses = vec![0.0; rows];
            forward(&mut probs, &mut losses, logits, &targets, rows, vp);
            losses.iter().sum::<f32>() / rows as f32
        };

        let mut probs = vec![0.0; rows * vp];
        let mut losses = vec![0.0; rows];
        forward(&mut probs, &mut losses, &logits, &targets, rows, vp);
        let mut dlogits = vec![0.0; rows * vp];
        backward(&mut dlogits, &probs, &targets, rows, vp);

        let h = 1e-3f32;
        for i in 0..rows * vp {
            let mut p = logits.clone();
            p[i] += h;
            let mut m = logits.clone();
            m[i] -= h;
            let fd = (loss(&p) - loss(&m)) / (2.0 * h);
            assert!((fd - dlogits[i]).abs() < 1e-3, "dlogits[{i}]: {fd} vs {}", dlogits[i]);
        }
    }
}
