//! AdamW with global-norm gradient clipping (llm.c gpt2_update).

/// Optimizer hyperparameters. Defaults match llm.c's fine-tuning setup and
//  the JAX artifact ABI (runtime::manifest::OptimizerAbi).
#[derive(Debug, Clone, Copy)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
}

impl Default for AdamW {
    fn default() -> Self {
        AdamW {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            grad_clip: 1.0,
        }
    }
}

impl AdamW {
    /// Global L2 norm of the gradient.
    pub fn grad_norm(grads: &[f32]) -> f32 {
        grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt() as f32
    }

    /// One update step (t counts from 1). Returns the pre-clip grad norm.
    pub fn step(
        &self,
        params: &mut [f32],
        grads: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        t: u32,
    ) -> f32 {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), m.len());
        assert_eq!(params.len(), v.len());
        let gnorm = Self::grad_norm(grads);
        let scale = (self.grad_clip / (gnorm + 1e-12)).min(1.0);
        let b1c = 1.0 - self.beta1.powi(t as i32);
        let b2c = 1.0 - self.beta2.powi(t as i32);
        for i in 0..params.len() {
            let g = grads[i] * scale;
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mhat = m[i] / b1c;
            let vhat = v[i] / b2c;
            params[i] -=
                self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
        gnorm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = x², grad = 2x: AdamW must drive x toward 0.
        let opt = AdamW {
            lr: 0.05,
            ..Default::default()
        };
        let mut x = vec![3.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        for t in 1..=200 {
            let g = vec![2.0 * x[0]];
            opt.step(&mut x, &g, &mut m, &mut v, t);
        }
        assert!(x[0].abs() < 0.1, "x = {}", x[0]);
    }

    #[test]
    fn clipping_bounds_update() {
        let opt = AdamW::default();
        let mut x = vec![0.0f32; 4];
        let mut m = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        let g = vec![1e6f32; 4]; // enormous gradient
        let gnorm = opt.step(&mut x, &g, &mut m, &mut v, 1);
        assert!(gnorm > 1e6);
        // With clip=1.0, the effective per-element grad is ≤ 1, so the
        // first-step update magnitude is ≈ lr.
        for &xi in &x {
            assert!(xi.abs() < 2.0 * opt.lr, "{xi}");
        }
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let opt = AdamW {
            lr: 0.1,
            weight_decay: 0.5,
            ..Default::default()
        };
        let mut x = vec![1.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        let g = vec![0.0f32];
        opt.step(&mut x, &g, &mut m, &mut v, 1);
        assert!(x[0] < 1.0);
    }

    #[test]
    fn bias_correction_first_step() {
        // With beta1=0.9, first-step mhat == g (bias-corrected).
        let opt = AdamW {
            lr: 1.0,
            eps: 0.0,
            ..Default::default()
        };
        let mut x = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        let g = vec![0.5f32];
        opt.step(&mut x, &g, &mut m, &mut v, 1);
        // update = lr * mhat/sqrt(vhat) = 1.0 * 0.5/0.5 = 1.0.
        assert!((x[0] + 1.0).abs() < 1e-5, "{}", x[0]);
    }
}
