//! Causal multi-head attention (llm.c attention_forward /
//! attention_backward). Stays on the CPU in the paper — only the GEMMs
//! around it are offloaded — so this is a faithful loop-nest port.

use crate::util::threads::parallel_for;

/// Forward. qkv is (B,T,3C) packed; out is (B,T,C); preatt/att are
/// (B,NH,T,T) caches for the backward pass.
pub fn forward(
    out: &mut [f32],
    preatt: &mut [f32],
    att: &mut [f32],
    qkv: &[f32],
    b: usize,
    t: usize,
    c: usize,
    nh: usize,
) {
    let hs = c / nh;
    let scale = 1.0 / (hs as f32).sqrt();
    let c3 = 3 * c;

    let out_addr = out.as_mut_ptr() as usize;
    let preatt_addr = preatt.as_mut_ptr() as usize;
    let att_addr = att.as_mut_ptr() as usize;
    let (out_len, preatt_len, att_len) = (out.len(), preatt.len(), att.len());

    parallel_for(b * nh, 1, |range| {
        // SAFETY: each (batch, head) pair touches disjoint slices of
        // out / preatt / att.
        let out = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, out_len) };
        let preatt =
            unsafe { std::slice::from_raw_parts_mut(preatt_addr as *mut f32, preatt_len) };
        let att = unsafe { std::slice::from_raw_parts_mut(att_addr as *mut f32, att_len) };
        for bh in range {
            let (bi, h) = (bh / nh, bh % nh);
            for ti in 0..t {
                let q = &qkv[(bi * t + ti) * c3 + h * hs..(bi * t + ti) * c3 + h * hs + hs];
                let pre_base = ((bi * nh + h) * t + ti) * t;
                let pre_row = &mut preatt[pre_base..pre_base + t];
                // Scores against all keys <= ti.
                let mut maxval = f32::MIN;
                for t2 in 0..=ti {
                    let k = &qkv
                        [(bi * t + t2) * c3 + c + h * hs..(bi * t + t2) * c3 + c + h * hs + hs];
                    let mut dot = 0.0f32;
                    for i in 0..hs {
                        dot += q[i] * k[i];
                    }
                    let v = dot * scale;
                    pre_row[t2] = v;
                    if v > maxval {
                        maxval = v;
                    }
                }
                // Softmax over the causal prefix.
                let att_row =
                    &mut att[((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                let mut sum = 0.0f32;
                for t2 in 0..=ti {
                    let e = (pre_row[t2] - maxval).exp();
                    att_row[t2] = e;
                    sum += e;
                }
                let inv = if sum == 0.0 { 0.0 } else { 1.0 / sum };
                for t2 in 0..t {
                    if t2 <= ti {
                        att_row[t2] *= inv;
                    } else {
                        att_row[t2] = 0.0;
                    }
                }
                // Weighted sum of values.
                let o = &mut out[(bi * t + ti) * c + h * hs..(bi * t + ti) * c + h * hs + hs];
                o.fill(0.0);
                for t2 in 0..=ti {
                    let v = &qkv[(bi * t + t2) * c3 + 2 * c + h * hs
                        ..(bi * t + t2) * c3 + 2 * c + h * hs + hs];
                    let a = att_row[t2];
                    for i in 0..hs {
                        o[i] += a * v[i];
                    }
                }
            }
        }
    });
}

/// Single-position decode forward against a KV-cache. `qkv_row` is one
/// position's packed (3C,) QKV GEMM output; `k_cache` / `v_cache` hold
/// `pos + 1` contiguous rows of C channels each (the caller writes this
/// position's K/V into the cache first); `att` is scratch of at least
/// `pos + 1` floats, reused per head. The float op order matches
/// [`forward`] exactly — same dot accumulation, max, exp/sum, and value
/// accumulation sequence — so a decoded output row is bit-identical to
/// the same position of a full-window forward.
pub fn forward_step(
    out: &mut [f32],
    att: &mut [f32],
    qkv_row: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: usize,
    c: usize,
    nh: usize,
) {
    let hs = c / nh;
    let scale = 1.0 / (hs as f32).sqrt();
    for h in 0..nh {
        let q = &qkv_row[h * hs..h * hs + hs];
        // Scores against all cached keys <= pos.
        let mut maxval = f32::MIN;
        for t2 in 0..=pos {
            let k = &k_cache[t2 * c + h * hs..t2 * c + h * hs + hs];
            let mut dot = 0.0f32;
            for i in 0..hs {
                dot += q[i] * k[i];
            }
            let v = dot * scale;
            att[t2] = v;
            if v > maxval {
                maxval = v;
            }
        }
        // Softmax over the causal prefix (in place: same value sequence
        // as the separate preatt/att buffers of the full forward).
        let mut sum = 0.0f32;
        for t2 in 0..=pos {
            let e = (att[t2] - maxval).exp();
            att[t2] = e;
            sum += e;
        }
        let inv = if sum == 0.0 { 0.0 } else { 1.0 / sum };
        for a in att[..=pos].iter_mut() {
            *a *= inv;
        }
        // Weighted sum of cached values.
        let o = &mut out[h * hs..h * hs + hs];
        o.fill(0.0);
        for t2 in 0..=pos {
            let v = &v_cache[t2 * c + h * hs..t2 * c + h * hs + hs];
            let a = att[t2];
            for i in 0..hs {
                o[i] += a * v[i];
            }
        }
    }
}

/// Backward: accumulates dqkv from dout using cached att (llm.c pattern:
/// dpreatt/datt are scratch).
pub fn backward(
    dqkv: &mut [f32],
    dpreatt: &mut [f32],
    datt: &mut [f32],
    dout: &[f32],
    qkv: &[f32],
    att: &[f32],
    b: usize,
    t: usize,
    c: usize,
    nh: usize,
) {
    let hs = c / nh;
    let scale = 1.0 / (hs as f32).sqrt();
    let c3 = 3 * c;
    // Serial over (b, h) — dqkv rows are shared across t, keep it simple
    // and deterministic (llm.c is also serial here modulo OpenMP collapse).
    for bi in 0..b {
        for h in 0..nh {
            for ti in 0..t {
                let att_row = &att[((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                let do_ = &dout[(bi * t + ti) * c + h * hs..(bi * t + ti) * c + h * hs + hs];

                // Backprop through the value accumulation.
                {
                    let datt_row = &mut datt
                        [((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                    for t2 in 0..=ti {
                        let v = &qkv[(bi * t + t2) * c3 + 2 * c + h * hs
                            ..(bi * t + t2) * c3 + 2 * c + h * hs + hs];
                        let mut d = 0.0f32;
                        for i in 0..hs {
                            d += v[i] * do_[i];
                        }
                        datt_row[t2] = d;
                    }
                }
                for t2 in 0..=ti {
                    let a = att_row[t2];
                    let dv_base = (bi * t + t2) * c3 + 2 * c + h * hs;
                    for i in 0..hs {
                        dqkv[dv_base + i] += a * do_[i];
                    }
                }

                // Backprop through softmax: dpre = att * (datt - Σ att·datt).
                {
                    let datt_row =
                        &datt[((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                    let dpre_row = &mut dpreatt
                        [((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                    let mut dot = 0.0f32;
                    for t2 in 0..=ti {
                        dot += att_row[t2] * datt_row[t2];
                    }
                    for t2 in 0..=ti {
                        dpre_row[t2] = att_row[t2] * (datt_row[t2] - dot);
                    }
                }

                // Backprop through q·k.
                let dpre_row =
                    &dpreatt[((bi * nh + h) * t + ti) * t..((bi * nh + h) * t + ti + 1) * t];
                let q_base = (bi * t + ti) * c3 + h * hs;
                for t2 in 0..=ti {
                    let k_base = (bi * t + t2) * c3 + c + h * hs;
                    let d = dpre_row[t2] * scale;
                    for i in 0..hs {
                        dqkv[q_base + i] += d * qkv[k_base + i];
                        dqkv[k_base + i] += d * qkv[q_base + i];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn attention_is_causal() {
        let (b, t, c, nh) = (1, 4, 8, 2);
        let mut rng = Rng::new(81);
        let mut qkv = prop::gen::normal_vec(&mut rng, b * t * 3 * c);
        let mut out1 = vec![0.0; b * t * c];
        let mut pre = vec![0.0; b * nh * t * t];
        let mut att = vec![0.0; b * nh * t * t];
        forward(&mut out1, &mut pre, &mut att, &qkv, b, t, c, nh);
        // Changing the LAST token's qkv must not affect earlier outputs.
        for v in qkv[(t - 1) * 3 * c..t * 3 * c].iter_mut() {
            *v += 1.0;
        }
        let mut out2 = vec![0.0; b * t * c];
        forward(&mut out2, &mut pre, &mut att, &qkv, b, t, c, nh);
        for i in 0..(t - 1) * c {
            assert_eq!(out1[i], out2[i], "causality violated at {i}");
        }
        assert!(out1[(t - 1) * c..] != out2[(t - 1) * c..]);
    }

    #[test]
    fn attention_rows_sum_to_one() {
        let (b, t, c, nh) = (2, 6, 12, 3);
        let mut rng = Rng::new(83);
        let qkv = prop::gen::normal_vec(&mut rng, b * t * 3 * c);
        let mut out = vec![0.0; b * t * c];
        let mut pre = vec![0.0; b * nh * t * t];
        let mut att = vec![0.0; b * nh * t * t];
        forward(&mut out, &mut pre, &mut att, &qkv, b, t, c, nh);
        for bh in 0..b * nh {
            for ti in 0..t {
                let row = &att[(bh * t + ti) * t..(bh * t + ti + 1) * t];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row sum {sum}");
                // Future positions masked.
                for t2 in ti + 1..t {
                    assert_eq!(row[t2], 0.0);
                }
            }
        }
    }

    #[test]
    fn forward_step_is_bit_identical_to_full_forward() {
        let (b, t, c, nh) = (1, 6, 16, 2);
        let mut rng = Rng::new(91);
        let qkv = prop::gen::normal_vec(&mut rng, b * t * 3 * c);
        let mut full = vec![0.0; b * t * c];
        let mut pre = vec![0.0; b * nh * t * t];
        let mut att = vec![0.0; b * nh * t * t];
        forward(&mut full, &mut pre, &mut att, &qkv, b, t, c, nh);

        // Build the caches the way decode does: one K/V row per position,
        // copied from the packed QKV rows.
        let mut k_cache = vec![0.0f32; t * c];
        let mut v_cache = vec![0.0f32; t * c];
        for pos in 0..t {
            let row = pos * 3 * c;
            k_cache[pos * c..(pos + 1) * c].copy_from_slice(&qkv[row + c..row + 2 * c]);
            v_cache[pos * c..(pos + 1) * c].copy_from_slice(&qkv[row + 2 * c..row + 3 * c]);
        }
        let mut out = vec![0.0f32; c];
        let mut scratch = vec![0.0f32; t];
        for pos in 0..t {
            forward_step(
                &mut out,
                &mut scratch,
                &qkv[pos * 3 * c..(pos + 1) * 3 * c],
                &k_cache[..(pos + 1) * c],
                &v_cache[..(pos + 1) * c],
                pos,
                c,
                nh,
            );
            assert_eq!(out, full[pos * c..(pos + 1) * c], "position {pos}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (b, t, c, nh) = (1, 3, 4, 2);
        let mut rng = Rng::new(89);
        let qkv = prop::gen::normal_vec(&mut rng, b * t * 3 * c);
        let dout = prop::gen::normal_vec(&mut rng, b * t * c);

        let loss = |qkv: &[f32]| -> f32 {
            let mut out = vec![0.0; b * t * c];
            let mut pre = vec![0.0; b * nh * t * t];
            let mut att = vec![0.0; b * nh * t * t];
            forward(&mut out, &mut pre, &mut att, qkv, b, t, c, nh);
            out.iter().zip(&dout).map(|(o, d)| o * d).sum()
        };

        let mut out = vec![0.0; b * t * c];
        let mut pre = vec![0.0; b * nh * t * t];
        let mut att = vec![0.0; b * nh * t * t];
        forward(&mut out, &mut pre, &mut att, &qkv, b, t, c, nh);

        let mut dqkv = vec![0.0; b * t * 3 * c];
        let mut dpre = vec![0.0; b * nh * t * t];
        let mut datt = vec![0.0; b * nh * t * t];
        backward(&mut dqkv, &mut dpre, &mut datt, &dout, &qkv, &att, b, t, c, nh);

        let h = 1e-3f32;
        for i in (0..b * t * 3 * c).step_by(5) {
            let mut p = qkv.clone();
            p[i] += h;
            let mut m = qkv.clone();
            m[i] -= h;
            let fd = (loss(&p) - loss(&m)) / (2.0 * h);
            assert!(
                (fd - dqkv[i]).abs() < 3e-2,
                "dqkv[{i}]: fd {fd} vs analytic {}",
                dqkv[i]
            );
        }
    }
}
