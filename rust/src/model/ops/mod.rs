//! The llm.c op kernels: forward + backward pairs.
//!
//! Each module mirrors one llm.c function pair (e.g. `layernorm_forward` /
//! `layernorm_backward`), with the same caching strategy and loop
//! structure. Matmuls go through [`matmul::MatmulDispatch`], the paper's
//! offload seam.

pub mod adamw;
pub mod attention;
pub mod classifier;
pub mod encoder;
pub mod gelu;
pub mod layernorm;
pub mod matmul;
pub mod residual;
