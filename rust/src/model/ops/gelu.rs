//! GELU, tanh approximation (llm.c gelu_forward / gelu_backward).

const GELU_SCALE: f32 = 0.797_884_6; // sqrt(2/pi)

#[inline]
fn gelu_scalar(x: f32) -> f32 {
    let cube = 0.044715 * x * x * x;
    0.5 * x * (1.0 + (GELU_SCALE * (x + cube)).tanh())
}

/// Elementwise forward.
pub fn forward(out: &mut [f32], inp: &[f32]) {
    for (o, &x) in out.iter_mut().zip(inp) {
        *o = gelu_scalar(x);
    }
}

/// dinp += gelu'(inp) * dout.
pub fn backward(dinp: &mut [f32], inp: &[f32], dout: &[f32]) {
    for i in 0..inp.len() {
        let x = inp[i];
        let cube = 0.044715 * x * x * x;
        let tanh_arg = GELU_SCALE * (x + cube);
        let tanh_out = tanh_arg.tanh();
        let cosh = tanh_arg.cosh();
        let sech2 = 1.0 / (cosh * cosh);
        let local = 0.5 * (1.0 + tanh_out)
            + x * 0.5 * sech2 * GELU_SCALE * (1.0 + 3.0 * 0.044715 * x * x);
        dinp[i] += local * dout[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let inp = [0.0f32, 1.0, -1.0, 3.0];
        let mut out = [0.0f32; 4];
        forward(&mut out, &inp);
        assert_eq!(out[0], 0.0);
        assert!((out[1] - 0.8411919906).abs() < 1e-4);
        assert!((out[2] + 0.158808).abs() < 1e-4);
        assert!((out[3] - 2.9963627).abs() < 1e-3);
    }

    #[test]
    fn backward_matches_finite_differences() {
        let inp: Vec<f32> = (-8..8).map(|i| i as f32 * 0.37).collect();
        let dout = vec![1.0f32; inp.len()];
        let mut dinp = vec![0.0f32; inp.len()];
        backward(&mut dinp, &inp, &dout);
        let h = 1e-3f32;
        for i in 0..inp.len() {
            let fd = (gelu_scalar(inp[i] + h) - gelu_scalar(inp[i] - h)) / (2.0 * h);
            assert!((fd - dinp[i]).abs() < 1e-2, "x={} fd {fd} vs {}", inp[i], dinp[i]);
        }
    }

    #[test]
    fn backward_accumulates() {
        let inp = [1.0f32];
        let dout = [2.0f32];
        let mut dinp = [5.0f32];
        backward(&mut dinp, &inp, &dout);
        assert!(dinp[0] > 5.0);
    }
}
