//! Matmul with bias — the offload seam (llm.c matmul_forward /
//! matmul_backward).
//!
//! llm.c weights are (OC, IC) row-major; activations are (BT, IC)
//! row-major. Forward computes out = inp · Wᵀ + bias. The dispatch enum
//! decides whether the GEMM runs on the llm.c-style CPU loop nest, is
//! offloaded eagerly through the session (the paper's modification), or
//! is *recorded* into a [`StepPlan`] so the whole training step can be
//! scheduled at once (the record→schedule→execute seam).

use crate::coordinator::executor::ExecClient;
use crate::coordinator::plan::{FusedEpilogue, PlanOp, PlanOpKind, PlanReplay, StepPlan};
use crate::coordinator::session::{GemmOp, InputLayout, OffloadSession, Ticket};
use crate::gemm::cpu;
use crate::gemm::sizes::ProblemSize;
use crate::util::error::Result;

/// Where matmuls execute. (`'a` borrows the session/plan for the step;
/// `'c` is the cache borrow a replay cursor carries.)
pub enum MatmulDispatch<'a, 'c> {
    /// Unmodified llm.c: multi-threaded f32 loop nest on the CPU.
    Cpu,
    /// The paper's version: offloaded to the NPU through an
    /// [`OffloadSession`], blocking per call (a legacy
    /// `GemmOffloadEngine` derefs to one, so both construct this variant).
    Npu(&'a mut OffloadSession),
    /// Record→schedule→execute: every GEMM is recorded into `plan`
    /// (numerics run immediately; the modeled schedule is deferred), with
    /// data dependencies chaining each layer's output to the next layer's
    /// input and weight staging marked prefetchable. The caller runs
    /// [`OffloadSession::execute`] on the plan after the step.
    Plan {
        session: &'a mut OffloadSession,
        plan: &'a mut StepPlan,
    },
    /// Cache-hit replay of a previously recorded step: every GEMM runs
    /// its numerics against this step's data while being checked against
    /// the cached plan (a shape change is a recoverable divergence — the
    /// trainer re-records), and the caller charges the frozen schedule
    /// once with [`OffloadSession::finish_replay`] after the step.
    Replay {
        session: &'a mut OffloadSession,
        replay: &'a mut PlanReplay<'c>,
    },
    /// Cache-hit replay with the device-stage loop on the background
    /// executor thread (`coordinator::executor`): the same checked op
    /// stream as [`MatmulDispatch::Replay`], but forward results are
    /// produced off-thread and the backward weight-gradient GEMMs are
    /// *deferred* — their accumulation happens when the result comes
    /// back, so the trainer's CPU ops overlap the `dW` staging + kernel
    /// in wallclock. Numerics stay bit-identical to the sync replay
    /// (invocations run in record order with identical inputs).
    BackgroundReplay {
        client: &'a mut ExecClient<'c>,
    },
    /// Quarantined-device degradation: the session stopped dispatching to
    /// its device after repeated faults (see `docs/RELIABILITY.md`), so
    /// every matmul runs the same multi-threaded f32 host loop nest as
    /// [`MatmulDispatch::Cpu`] — bit-identical outputs, because host ops
    /// are the oracle every offload rung is pinned to — while the
    /// session's fault ledger counts the fallback work
    /// (`FaultCounters::fallback_ops`).
    HostFallback(&'a mut OffloadSession),
}

impl MatmulDispatch<'_, '_> {
    /// Does this dispatch offload through the session (eagerly or via a
    /// recorded plan)? Host fallback does not: it computes on the host
    /// oracle and only counts against the session.
    pub fn is_npu(&self) -> bool {
        !matches!(self, MatmulDispatch::Cpu | MatmulDispatch::HostFallback(_))
    }
}

/// out(BT,OC) = inp(BT,IC) · W(OC,IC)ᵀ + bias(OC).
pub fn forward(
    dispatch: &mut MatmulDispatch,
    out: &mut [f32],
    inp: &[f32],
    weight: &[f32],
    bias: Option<&[f32]>,
    bt: usize,
    ic: usize,
    oc: usize,
) -> Result<()> {
    forward_hinted(
        dispatch,
        out,
        inp,
        weight,
        bias,
        bt,
        ic,
        oc,
        FusedEpilogue::None,
        false,
    )
}

/// [`forward`] with block-offload hints: `fused` marks an epilogue the
/// vector units apply while the output strip drains (modeled free), and
/// `resident` marks the activation input as already device-resident —
/// the previous chained op (a recorded layernorm, or a fused-gelu GEMM)
/// left it in a device BO, so the modeled schedule charges no host A
/// staging, no A input sync, and no per-op dispatch doorbell. Numerics
/// are unchanged in every arm: residency is a *modeling* property of
/// the plan; the physical record path still runs the host-op baseline
/// bit-for-bit.
pub fn forward_hinted(
    dispatch: &mut MatmulDispatch,
    out: &mut [f32],
    inp: &[f32],
    weight: &[f32],
    bias: Option<&[f32]>,
    bt: usize,
    ic: usize,
    oc: usize,
    fused: FusedEpilogue,
    resident: bool,
) -> Result<()> {
    match dispatch {
        MatmulDispatch::Cpu => {
            // C = A · Bᵀ computed as the llm.c loop nest: for each row,
            // accumulate over IC. We reuse the blocked row kernel by
            // multiplying against the transposed weight view.
            cpu_matmul_bt(out, inp, weight, bt, ic, oc);
        }
        MatmulDispatch::Npu(session) => {
            if session.quarantined() {
                // The device is quarantined mid-run: degrade this op to
                // the host oracle instead of surfacing a dead device.
                cpu_matmul_bt(out, inp, weight, bt, ic, oc);
                session.faults.fallback_ops += 1;
            } else {
                // The session wants B as (IC, OC) row-major; W is (OC, IC)
                // row-major = exactly the "column-major weights" the paper
                // transposes on copy (InputLayout::Transposed).
                let size = ProblemSize::new(bt, ic, oc);
                session.gemm(size, inp, weight, InputLayout::Transposed, out)?;
            }
        }
        MatmulDispatch::HostFallback(session) => {
            cpu_matmul_bt(out, inp, weight, bt, ic, oc);
            session.faults.fallback_ops += 1;
        }
        MatmulDispatch::Plan { session, plan } => {
            // Record instead of blocking: the activation input chains on
            // the previous recorded op's output; the weight (B) is known
            // ahead of the step, so its staging may prefetch under an
            // earlier kernel.
            let size = ProblemSize::new(bt, ic, oc);
            let mut op = PlanOp::new(size)
                .with_b_layout(InputLayout::Transposed)
                .prefetchable_b(true)
                .with_fused(fused)
                .resident_input(resident);
            if let Some(head) = plan.chain_head() {
                op = op.after(head);
            }
            let node = session.record_gemm(plan, &op, inp, weight, out)?;
            plan.set_chain(node);
        }
        MatmulDispatch::Replay { session, replay } => {
            // Identical op description to the record arm, checked against
            // the cached plan; numerics run with this step's data.
            let size = ProblemSize::new(bt, ic, oc);
            let mut op = PlanOp::new(size)
                .with_b_layout(InputLayout::Transposed)
                .prefetchable_b(true)
                .with_fused(fused)
                .resident_input(resident);
            if let Some(head) = replay.chain_head() {
                op = op.after(head);
            }
            let node = session.replay_gemm(replay, &op, inp, weight, out)?;
            replay.set_chain(node);
        }
        MatmulDispatch::BackgroundReplay { client } => {
            // Same checked op stream as the Replay arm; the invocation
            // runs on the executor thread. A forward output feeds the
            // next CPU op immediately, so the wait stays in this call.
            let size = ProblemSize::new(bt, ic, oc);
            let mut op = PlanOp::new(size)
                .with_b_layout(InputLayout::Transposed)
                .prefetchable_b(true)
                .with_fused(fused)
                .resident_input(resident);
            if let Some(head) = client.chain_head() {
                op = op.after(head);
            }
            // SAFETY: the handle is waited below, before inp/weight/out
            // leave this frame's borrows; on error the client quiesces
            // the executor before returning.
            let (node, handle) = unsafe { client.submit(&op, inp, weight, out)? };
            client.set_chain(node);
            client.wait(handle)?;
        }
    }
    if let Some(bias) = bias {
        for r in 0..bt {
            let row = &mut out[r * oc..(r + 1) * oc];
            for i in 0..oc {
                row[i] += bias[i];
            }
        }
    }
    Ok(())
}

/// Thread one *elementwise* transformer site (layernorm / gelu /
/// softmax) through the plan path. The host numerics already ran (or
/// are about to run) on the caller's thread — this records, replays, or
/// advances past the op's *modeled* device cost only, chained on the
/// activation head like a GEMM so residency edges survive scheduling.
/// `rows * cols` f32 elements stream through the vector units;
/// `resident` marks the input as left device-resident by the previous
/// chained op (the softmax-at-classifier case, fed by the lm-head).
/// `Cpu` and eager `Npu` dispatches are a no-op: elementwise offload
/// exists only where a step plan exists.
pub fn elementwise(
    dispatch: &mut MatmulDispatch,
    kind: PlanOpKind,
    rows: usize,
    cols: usize,
    resident: bool,
) -> Result<()> {
    let size = ProblemSize::new(rows, 1, cols);
    match dispatch {
        // Elementwise numerics always run on the host; without a step
        // plan (and on a quarantined session) there is no modeled device
        // cost to record either.
        MatmulDispatch::Cpu | MatmulDispatch::Npu(_) | MatmulDispatch::HostFallback(_) => {}
        MatmulDispatch::Plan { session, plan } => {
            let mut op = PlanOp::elementwise(kind, size).resident_input(resident);
            if let Some(head) = plan.chain_head() {
                op = op.after(head);
            }
            let node = session.record_elementwise(plan, &op)?;
            plan.set_chain(node);
        }
        MatmulDispatch::Replay { session, replay } => {
            let mut op = PlanOp::elementwise(kind, size).resident_input(resident);
            if let Some(head) = replay.chain_head() {
                op = op.after(head);
            }
            let node = session.replay_elementwise(replay, &op)?;
            replay.set_chain(node);
        }
        MatmulDispatch::BackgroundReplay { client } => {
            // No job crosses the executor queue — the cursor advance is
            // checked against the cached plan on this thread.
            let mut op = PlanOp::elementwise(kind, size).resident_input(resident);
            if let Some(head) = client.chain_head() {
                op = op.after(head);
            }
            let node = client.advance_elementwise(&op)?;
            client.set_chain(node);
        }
    }
    Ok(())
}

/// dinp += dout · W ; dweight += doutᵀ · inp ; dbias += Σ_rows dout.
///
/// `dw_off` is `dweight`'s offset inside the model's gradient arena
/// (`ParamTensors::as_mut_slice`). Only the `BackgroundReplay` arm uses
/// it: the deferred dW job names its accumulation target by that offset
/// (no pointer crosses the executor thread boundary) and the trainer
/// applies it at step end via `ExecClient::drain_and_apply`. Every other
/// arm accumulates through `dweight` directly and ignores the offset.
///
/// `dout_stable` is the caller's promise that the `dout` buffer stays
/// valid and unmutated until the step finishes — the model's
/// parity-rotated `dout` scratches and the once-per-step lm-head
/// `d_logits` qualify. When true, the `BackgroundReplay` arm borrows
/// `dout` for the deferred `dW` job zero-copy
/// ([`ExecClient::submit_deferred_borrowed`]); when false it pays the
/// copy. Every other arm ignores the flag.
pub fn backward(
    dispatch: &mut MatmulDispatch,
    dinp: &mut [f32],
    dweight: &mut [f32],
    dw_off: usize,
    dbias: Option<&mut [f32]>,
    dout: &[f32],
    dout_stable: bool,
    inp: &[f32],
    weight: &[f32],
    bt: usize,
    ic: usize,
    oc: usize,
) -> Result<()> {
    match dispatch {
        MatmulDispatch::Cpu => {
            cpu_backward(dinp, dweight, dout, inp, weight, bt, ic, oc);
        }
        MatmulDispatch::HostFallback(session) => {
            // Bit-identical to the Cpu arm (same routine); the session's
            // fault ledger counts both degraded GEMMs.
            cpu_backward(dinp, dweight, dout, inp, weight, bt, ic, oc);
            session.faults.fallback_ops += 2;
        }
        MatmulDispatch::Npu(session) if session.quarantined() => {
            cpu_backward(dinp, dweight, dout, inp, weight, bt, ic, oc);
            session.faults.fallback_ops += 2;
        }
        MatmulDispatch::Npu(session) => {
            // Both backward GEMMs are offloaded — they are Figure 6's
            // backward problem sizes. They read the same inputs and write
            // disjoint outputs, so they stream through the one submit/wait
            // path at any ring depth: when the ring is full the oldest
            // submission retires first, which at depth 1 degenerates to
            // the paper's serial submit→wait and at depth ≥ 2 overlaps
            // the second invocation's host staging with the first's
            // kernel (and lets the scheduler batch them).
            let mut tmp = vec![0.0f32; bt * ic];
            let mut dw = vec![0.0f32; oc * ic];
            let dinp_size = ProblemSize::new(bt, oc, ic);
            let dw_size = ProblemSize::new(oc, bt, ic);
            let ops: [(GemmOp, &[f32], &[f32]); 2] = [
                (GemmOp::new(dinp_size), dout, weight),
                (
                    // dout is (BT,OC): Mᵀ view
                    GemmOp::new(dw_size).with_a_layout(InputLayout::Transposed),
                    dout,
                    inp,
                ),
            ];
            let mut outs = [&mut tmp, &mut dw];
            let mut pending: Vec<(Ticket, usize)> = Vec::new();
            for (i, (op, a, b)) in ops.iter().enumerate() {
                if session.in_flight() >= session.queue_depth() {
                    let (t, j) = pending.remove(0);
                    session.wait(t, &mut outs[j][..])?;
                }
                pending.push((session.submit(op, a, b)?, i));
            }
            for (t, j) in pending {
                session.wait(t, &mut outs[j][..])?;
            }
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
            for (d, t) in dweight.iter_mut().zip(&dw) {
                *d += t;
            }
        }
        MatmulDispatch::Plan { session, plan } => {
            // Record both backward GEMMs. Each depends on dout — the
            // activation-chain head — but not on each other; the chain
            // advances through dinp (the gradient that flows on to the
            // previous layer), leaving dW a batchable leaf. Both B inputs
            // (the weight, and the activation saved by the forward pass)
            // are known before the step executes: prefetchable.
            let mut tmp = vec![0.0f32; bt * ic];
            let mut dw = vec![0.0f32; oc * ic];
            let dinp_size = ProblemSize::new(bt, oc, ic);
            let dw_size = ProblemSize::new(oc, bt, ic);
            let head = plan.chain_head();
            let mut op_dinp = PlanOp::new(dinp_size).prefetchable_b(true);
            let mut op_dw = PlanOp::new(dw_size)
                .with_a_layout(InputLayout::Transposed) // dout is (BT,OC): Mᵀ view
                .prefetchable_b(true);
            if let Some(h) = head {
                op_dinp = op_dinp.after(h);
                op_dw = op_dw.after(h);
            }
            let n_dinp = session.record_gemm(plan, &op_dinp, dout, weight, &mut tmp)?;
            session.record_gemm(plan, &op_dw, dout, inp, &mut dw)?;
            plan.set_chain(n_dinp);
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
            for (d, t) in dweight.iter_mut().zip(&dw) {
                *d += t;
            }
        }
        MatmulDispatch::Replay { session, replay } => {
            // The record arm's (dinp, dW) pair, checked against the
            // cached plan op for op.
            let mut tmp = vec![0.0f32; bt * ic];
            let mut dw = vec![0.0f32; oc * ic];
            let dinp_size = ProblemSize::new(bt, oc, ic);
            let dw_size = ProblemSize::new(oc, bt, ic);
            let head = replay.chain_head();
            let mut op_dinp = PlanOp::new(dinp_size).prefetchable_b(true);
            let mut op_dw = PlanOp::new(dw_size)
                .with_a_layout(InputLayout::Transposed) // dout is (BT,OC): Mᵀ view
                .prefetchable_b(true);
            if let Some(h) = head {
                op_dinp = op_dinp.after(h);
                op_dw = op_dw.after(h);
            }
            let n_dinp = session.replay_gemm(replay, &op_dinp, dout, weight, &mut tmp)?;
            session.replay_gemm(replay, &op_dw, dout, inp, &mut dw)?;
            replay.set_chain(n_dinp);
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
            for (d, t) in dweight.iter_mut().zip(&dw) {
                *d += t;
            }
        }
        MatmulDispatch::BackgroundReplay { client } => {
            // The Replay arm's (dinp, dW) pair, with the device-stage
            // work on the executor thread. dinp is waited here (the
            // gradient chain needs it), but the weight gradient is
            // needed only by the optimizer at step end, so it *defers*:
            // the executor runs its staging + kernel + merge while this
            // thread moves on to the layer's remaining CPU backward ops
            // (gelu, layernorm, attention), and the client accumulates
            // into dweight when the result arrives — staging + device
            // wallclock hidden for real, not just on the modeled
            // timeline.
            let mut tmp = vec![0.0f32; bt * ic];
            let dinp_size = ProblemSize::new(bt, oc, ic);
            let dw_size = ProblemSize::new(oc, bt, ic);
            let head = client.chain_head();
            let mut op_dinp = PlanOp::new(dinp_size).prefetchable_b(true);
            let mut op_dw = PlanOp::new(dw_size)
                .with_a_layout(InputLayout::Transposed) // dout is (BT,OC): Mᵀ view
                .prefetchable_b(true);
            if let Some(h) = head {
                op_dinp = op_dinp.after(h);
                op_dw = op_dw.after(h);
            }
            // Unless the caller promises `dout` is step-stable, it is
            // copied for the deferred job (a reused gradient scratch is
            // not stable beyond this call); copying *before* the first
            // submit keeps the submit→wait window free of panic-prone
            // work (allocation), per the submit safety contract.
            let dout_copy = if dout_stable { None } else { Some(dout.to_vec()) };
            // SAFETY: h_dinp is waited below, before dout/weight/tmp
            // leave this frame's borrows; on error the client quiesces
            // the executor before returning; nothing between the
            // submits and the wait can unwind.
            let (n_dinp, h_dinp) = unsafe { client.submit(&op_dinp, dout, weight, &mut tmp)? };
            // The dW target is named by arena offset; the trainer applies
            // the accumulation at step end (drain_and_apply), after this
            // frame's dweight borrow is long gone.
            // SAFETY: inp is a saved forward activation, stable for the
            // whole step; a borrowed dout is the caller's `dout_stable`
            // promise (the model's parity-rotated scratches) — exactly
            // the submit_deferred / submit_deferred_borrowed contracts.
            unsafe {
                match dout_copy {
                    Some(copy) => {
                        client.submit_deferred(&op_dw, copy, inp, dw_off, dweight.len())?
                    }
                    None => client.submit_deferred_borrowed(
                        &op_dw,
                        dout,
                        inp,
                        dw_off,
                        dweight.len(),
                    )?,
                }
            };
            client.set_chain(n_dinp);
            client.wait(h_dinp)?;
            // This merge (and the bias reduction below) overlaps the
            // executor's dW invocation.
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
        }
    }
    if let Some(dbias) = dbias {
        for r in 0..bt {
            let row = &dout[r * oc..(r + 1) * oc];
            for i in 0..oc {
                dbias[i] += row[i];
            }
        }
    }
    Ok(())
}

/// The host-oracle backward pair: dinp += dout · W and
/// dweight += doutᵀ · inp (the [`MatmulDispatch::Cpu`] and
/// [`MatmulDispatch::HostFallback`] arms share it, which is what makes
/// quarantine degradation bit-identical to the CPU baseline).
fn cpu_backward(
    dinp: &mut [f32],
    dweight: &mut [f32],
    dout: &[f32],
    inp: &[f32],
    weight: &[f32],
    bt: usize,
    ic: usize,
    oc: usize,
) {
    // dinp(BT,IC) += dout(BT,OC) · W(OC,IC).
    let mut tmp = vec![0.0f32; bt * ic];
    cpu::gemm_f32(dout, weight, &mut tmp, bt, oc, ic);
    for (d, t) in dinp.iter_mut().zip(&tmp) {
        *d += t;
    }
    // dweight(OC,IC) += doutᵀ(OC,BT) · inp(BT,IC).
    let mut dw = vec![0.0f32; oc * ic];
    let mut dout_t = vec![0.0f32; oc * bt];
    crate::coordinator::transpose::transpose(dout, &mut dout_t, bt, oc);
    cpu::gemm_f32(&dout_t, inp, &mut dw, oc, bt, ic);
    for (d, t) in dweight.iter_mut().zip(&dw) {
        *d += t;
    }
}

/// C(BT,OC) = A(BT,IC) · W(OC,IC)ᵀ, llm.c-style parallel loop nest.
fn cpu_matmul_bt(out: &mut [f32], inp: &[f32], weight: &[f32], bt: usize, ic: usize, oc: usize) {
    use crate::util::threads::parallel_for;
    let out_addr = out.as_mut_ptr() as usize;
    parallel_for(bt, 4, |rows| {
        // SAFETY: disjoint row ranges.
        let out_all = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, bt * oc) };
        for r in rows {
            let a_row = &inp[r * ic..(r + 1) * ic];
            let o_row = &mut out_all[r * oc..(r + 1) * oc];
            for o in 0..oc {
                let w_row = &weight[o * ic..(o + 1) * ic];
                let mut acc = 0.0f32;
                for i in 0..ic {
                    acc += a_row[i] * w_row[i];
                }
                o_row[o] = acc;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineConfig, GemmOffloadEngine};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand(rng: &mut Rng, n: usize) -> Vec<f32> {
        prop::gen::normal_vec(rng, n)
    }

    #[test]
    fn cpu_forward_matches_reference() {
        let (bt, ic, oc) = (8, 12, 16);
        let mut rng = Rng::new(61);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let bias = rand(&mut rng, oc);
        let mut out = vec![0.0; bt * oc];
        forward(&mut MatmulDispatch::Cpu, &mut out, &inp, &w, Some(&bias), bt, ic, oc).unwrap();
        for r in 0..bt {
            for o in 0..oc {
                let mut acc = bias[o];
                for i in 0..ic {
                    acc += inp[r * ic + i] * w[o * ic + i];
                }
                assert!((out[r * oc + o] - acc).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn npu_forward_matches_cpu_within_bf16() {
        let (bt, ic, oc) = (64, 64, 128);
        let mut rng = Rng::new(67);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let bias = rand(&mut rng, oc);
        let mut out_cpu = vec![0.0; bt * oc];
        forward(&mut MatmulDispatch::Cpu, &mut out_cpu, &inp, &w, Some(&bias), bt, ic, oc)
            .unwrap();
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let mut out_npu = vec![0.0; bt * oc];
        forward(
            &mut MatmulDispatch::Npu(&mut eng),
            &mut out_npu,
            &inp,
            &w,
            Some(&bias),
            bt,
            ic,
            oc,
        )
        .unwrap();
        for (x, y) in out_npu.iter().zip(&out_cpu) {
            assert!((x - y).abs() <= 0.06 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (bt, ic, oc) = (3, 4, 5);
        let mut rng = Rng::new(71);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let loss = |inp: &[f32], w: &[f32]| -> f32 {
            let mut out = vec![0.0; bt * oc];
            forward(&mut MatmulDispatch::Cpu, &mut out, inp, w, None, bt, ic, oc).unwrap();
            out.iter().zip(&dout).map(|(o, d)| o * d).sum()
        };

        let mut dinp = vec![0.0; bt * ic];
        let mut dw = vec![0.0; oc * ic];
        let mut dbias = vec![0.0; oc];
        backward(
            &mut MatmulDispatch::Cpu,
            &mut dinp,
            &mut dw,
            0,
            Some(&mut dbias),
            &dout,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();

        let h = 1e-3f32;
        for i in [0usize, bt * ic - 1, 5] {
            let mut p = inp.clone();
            p[i] += h;
            let mut m = inp.clone();
            m[i] -= h;
            let fd = (loss(&p, &w) - loss(&m, &w)) / (2.0 * h);
            assert!((fd - dinp[i]).abs() < 2e-2, "dinp[{i}] {fd} vs {}", dinp[i]);
        }
        for i in [0usize, oc * ic - 1] {
            let mut p = w.to_vec();
            p[i] += h;
            let mut m = w.to_vec();
            m[i] -= h;
            let fd = (loss(&inp, &p) - loss(&inp, &m)) / (2.0 * h);
            assert!((fd - dw[i]).abs() < 2e-2, "dw[{i}] {fd} vs {}", dw[i]);
        }
        // dbias = column sums of dout.
        for o in 0..oc {
            let expect: f32 = (0..bt).map(|r| dout[r * oc + o]).sum();
            assert!((dbias[o] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn npu_backward_matches_cpu_backward() {
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(73);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut dinp_c = vec![0.0; bt * ic];
        let mut dw_c = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Cpu, &mut dinp_c, &mut dw_c, 0, None, &dout, false, &inp, &w, bt,
            ic, oc,
        )
        .unwrap();

        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let mut dinp_n = vec![0.0; bt * ic];
        let mut dw_n = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Npu(&mut eng),
            &mut dinp_n,
            &mut dw_n,
            0,
            None,
            &dout,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();

        // bf16 quantization noise: with K=64 zero-mean products, absolute
        // error up to ~sum|terms| * 2^-8; use an absolute-dominated bound.
        for (x, y) in dinp_n.iter().zip(&dinp_c) {
            assert!((x - y).abs() <= 0.12 + 0.02 * y.abs(), "{x} vs {y}");
        }
        for (x, y) in dw_n.iter().zip(&dw_c) {
            assert!((x - y).abs() <= 0.12 + 0.02 * y.abs(), "{x} vs {y}");
        }
    }

    #[test]
    fn deeper_ring_backward_bit_identical_to_serial_and_overlaps() {
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(79);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut run = |depth: usize| {
            let mut sess = OffloadSession::new(
                SessionConfig {
                    depth: QueueDepth(depth),
                    ..Default::default()
                },
                &[],
            )
            .unwrap();
            let mut dinp = vec![0.0; bt * ic];
            let mut dw = vec![0.0; oc * ic];
            backward(
                &mut MatmulDispatch::Npu(&mut sess),
                &mut dinp,
                &mut dw,
                0,
                None,
                &dout,
                false,
                &inp,
                &w,
                bt,
                ic,
                oc,
            )
            .unwrap();
            let hidden = sess.pipeline.hidden_s();
            (dinp, dw, hidden)
        };
        let (dinp_s, dw_s, hidden_s) = run(1);
        let (dinp_p, dw_p, hidden_p) = run(2);
        assert_eq!(dinp_s, dinp_p, "ring depth must not change numerics");
        assert_eq!(dw_s, dw_p);
        assert_eq!(hidden_s, 0.0, "depth-1 (serial) schedule has no overlap");
        assert!(hidden_p > 0.0, "paired backward GEMMs must overlap");
    }

    #[test]
    fn recorded_backward_bit_identical_to_eager_and_leaves_dw_batchable() {
        use crate::coordinator::plan::StepPlan;
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(101);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut eager_sess = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
        let mut dinp_e = vec![0.0; bt * ic];
        let mut dw_e = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Npu(&mut eager_sess),
            &mut dinp_e,
            &mut dw_e,
            0,
            None,
            &dout,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();

        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = StepPlan::new();
        let mut dinp_p = vec![0.0; bt * ic];
        let mut dw_p = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Plan {
                session: &mut sess,
                plan: &mut plan,
            },
            &mut dinp_p,
            &mut dw_p,
            0,
            None,
            &dout,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();
        assert_eq!(dinp_e, dinp_p, "recording must not change numerics");
        assert_eq!(dw_e, dw_p);
        assert_eq!(plan.len(), 2, "both backward GEMMs recorded");
        // dinp heads the chain; dW is a dependency-free leaf the scheduler
        // may batch across layers.
        assert_eq!(plan.chain_head().unwrap().index(), 0);
        let report = sess.execute(&mut plan).unwrap();
        assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
        assert!(
            report.hidden_growth_s() > 0.0,
            "paired backward GEMMs must overlap in the replay"
        );
    }

    #[test]
    fn replay_dispatch_reruns_backward_against_the_cached_plan() {
        use crate::coordinator::plan::{PlanCache, StepPlan};
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(103);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();

        // Step 1: record + execute + cache.
        let mut plan = StepPlan::new();
        let mut dinp_r = vec![0.0; bt * ic];
        let mut dw_r = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Plan {
                session: &mut sess,
                plan: &mut plan,
            },
            &mut dinp_r,
            &mut dw_r,
            0,
            None,
            &dout,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();
        sess.execute(&mut plan).unwrap();
        let mut cache = PlanCache::new();
        cache.insert(sess.freeze(plan).unwrap());

        // Step 2: the same backward through the replay dispatch — new
        // data, cached schedule.
        let dout2: Vec<f32> = dout.iter().map(|x| x * 2.0).collect();
        let mut dinp_p = vec![0.0; bt * ic];
        let mut dw_p = vec![0.0; oc * ic];
        let mut replay = sess.begin_replay(&cache).expect("cached for this session");
        backward(
            &mut MatmulDispatch::Replay {
                session: &mut sess,
                replay: &mut replay,
            },
            &mut dinp_p,
            &mut dw_p,
            0,
            None,
            &dout2,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();
        let report = sess.finish_replay(replay).unwrap();
        assert_eq!(report.stats.len(), 2);

        // The replayed numerics are this step's data through the same
        // bit-exact path as an eager backward with dout2.
        let mut eager = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
        let mut dinp_e = vec![0.0; bt * ic];
        let mut dw_e = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Npu(&mut eager),
            &mut dinp_e,
            &mut dw_e,
            0,
            None,
            &dout2,
            false,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();
        assert_eq!(dinp_p, dinp_e, "replayed numerics must track this step's data");
        assert_eq!(dw_p, dw_e);

        // A shape change diverges recoverably instead of mischarging.
        let mut replay = sess.begin_replay(&cache).unwrap();
        let err = backward(
            &mut MatmulDispatch::Replay {
                session: &mut sess,
                replay: &mut replay,
            },
            &mut vec![0.0; bt * 2 * ic],
            &mut dw_p,
            0,
            None,
            &rand(&mut rng, bt * 2 * oc),
            false,
            &rand(&mut rng, bt * 2 * ic),
            &w,
            bt * 2,
            ic,
            oc,
        )
        .unwrap_err();
        assert!(err.is_plan_divergence(), "{err}");
    }
}
