//! Matmul with bias — the offload seam (llm.c matmul_forward /
//! matmul_backward).
//!
//! llm.c weights are (OC, IC) row-major; activations are (BT, IC)
//! row-major. Forward computes out = inp · Wᵀ + bias. The dispatch enum
//! decides whether the GEMM runs on the llm.c-style CPU loop nest or is
//! offloaded through the engine (the paper's modification).

use crate::coordinator::session::{GemmOp, InputLayout, OffloadSession};
use crate::gemm::cpu;
use crate::gemm::sizes::ProblemSize;
use crate::util::error::Result;

/// Where matmuls execute.
pub enum MatmulDispatch<'a> {
    /// Unmodified llm.c: multi-threaded f32 loop nest on the CPU.
    Cpu,
    /// The paper's version: offloaded to the NPU through an
    /// [`OffloadSession`] (a legacy `GemmOffloadEngine` derefs to one, so
    /// both construct this variant).
    Npu(&'a mut OffloadSession),
}

impl MatmulDispatch<'_> {
    pub fn is_npu(&self) -> bool {
        matches!(self, MatmulDispatch::Npu(_))
    }
}

/// out(BT,OC) = inp(BT,IC) · W(OC,IC)ᵀ + bias(OC).
pub fn forward(
    dispatch: &mut MatmulDispatch,
    out: &mut [f32],
    inp: &[f32],
    weight: &[f32],
    bias: Option<&[f32]>,
    bt: usize,
    ic: usize,
    oc: usize,
) -> Result<()> {
    match dispatch {
        MatmulDispatch::Cpu => {
            // C = A · Bᵀ computed as the llm.c loop nest: for each row,
            // accumulate over IC. We reuse the blocked row kernel by
            // multiplying against the transposed weight view.
            cpu_matmul_bt(out, inp, weight, bt, ic, oc);
        }
        MatmulDispatch::Npu(session) => {
            // The session wants B as (IC, OC) row-major; W is (OC, IC)
            // row-major = exactly the "column-major weights" the paper
            // transposes on copy (InputLayout::Transposed).
            let size = ProblemSize::new(bt, ic, oc);
            session.gemm(size, inp, weight, InputLayout::Transposed, out)?;
        }
    }
    if let Some(bias) = bias {
        for r in 0..bt {
            let row = &mut out[r * oc..(r + 1) * oc];
            for i in 0..oc {
                row[i] += bias[i];
            }
        }
    }
    Ok(())
}

/// dinp += dout · W ; dweight += doutᵀ · inp ; dbias += Σ_rows dout.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    dispatch: &mut MatmulDispatch,
    dinp: &mut [f32],
    dweight: &mut [f32],
    dbias: Option<&mut [f32]>,
    dout: &[f32],
    inp: &[f32],
    weight: &[f32],
    bt: usize,
    ic: usize,
    oc: usize,
) -> Result<()> {
    match dispatch {
        MatmulDispatch::Cpu => {
            // dinp(BT,IC) += dout(BT,OC) · W(OC,IC).
            let mut tmp = vec![0.0f32; bt * ic];
            cpu::gemm_f32(dout, weight, &mut tmp, bt, oc, ic);
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
            // dweight(OC,IC) += doutᵀ(OC,BT) · inp(BT,IC).
            let mut dw = vec![0.0f32; oc * ic];
            let mut dout_t = vec![0.0f32; oc * bt];
            crate::coordinator::transpose::transpose(dout, &mut dout_t, bt, oc);
            cpu::gemm_f32(&dout_t, inp, &mut dw, oc, bt, ic);
            for (d, t) in dweight.iter_mut().zip(&dw) {
                *d += t;
            }
        }
        MatmulDispatch::Npu(session) => {
            // Both backward GEMMs are offloaded — they are Figure 6's
            // backward problem sizes. They read the same inputs and write
            // disjoint outputs, so a ring deep enough for two submissions
            // overlaps the second invocation's host staging with the
            // first's kernel (and lets the scheduler batch them).
            let mut tmp = vec![0.0f32; bt * ic];
            let mut dw = vec![0.0f32; oc * ic];
            let dinp_size = ProblemSize::new(bt, oc, ic);
            let dw_size = ProblemSize::new(oc, bt, ic);
            if session.queue_depth() >= 2 {
                let t_dinp = session.submit(&GemmOp::new(dinp_size), dout, weight)?;
                let t_dw = session.submit(
                    &GemmOp::new(dw_size)
                        .with_a_layout(InputLayout::Transposed), // dout is (BT,OC): Mᵀ view
                    dout,
                    inp,
                )?;
                session.wait(t_dinp, &mut tmp)?;
                session.wait(t_dw, &mut dw)?;
            } else {
                session.gemm(dinp_size, dout, weight, InputLayout::RowMajor, &mut tmp)?;
                session.gemm_ex(
                    dw_size,
                    dout,
                    InputLayout::Transposed, // dout is (BT,OC): Mᵀ view
                    inp,
                    InputLayout::RowMajor,
                    &mut dw,
                )?;
            }
            for (d, t) in dinp.iter_mut().zip(&tmp) {
                *d += t;
            }
            for (d, t) in dweight.iter_mut().zip(&dw) {
                *d += t;
            }
        }
    }
    if let Some(dbias) = dbias {
        for r in 0..bt {
            let row = &dout[r * oc..(r + 1) * oc];
            for i in 0..oc {
                dbias[i] += row[i];
            }
        }
    }
    Ok(())
}

/// C(BT,OC) = A(BT,IC) · W(OC,IC)ᵀ, llm.c-style parallel loop nest.
fn cpu_matmul_bt(out: &mut [f32], inp: &[f32], weight: &[f32], bt: usize, ic: usize, oc: usize) {
    use crate::util::threads::parallel_for;
    let out_addr = out.as_mut_ptr() as usize;
    parallel_for(bt, 4, |rows| {
        // SAFETY: disjoint row ranges.
        let out_all = unsafe { std::slice::from_raw_parts_mut(out_addr as *mut f32, bt * oc) };
        for r in rows {
            let a_row = &inp[r * ic..(r + 1) * ic];
            let o_row = &mut out_all[r * oc..(r + 1) * oc];
            for o in 0..oc {
                let w_row = &weight[o * ic..(o + 1) * ic];
                let mut acc = 0.0f32;
                for i in 0..ic {
                    acc += a_row[i] * w_row[i];
                }
                o_row[o] = acc;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineConfig, GemmOffloadEngine};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand(rng: &mut Rng, n: usize) -> Vec<f32> {
        prop::gen::normal_vec(rng, n)
    }

    #[test]
    fn cpu_forward_matches_reference() {
        let (bt, ic, oc) = (8, 12, 16);
        let mut rng = Rng::new(61);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let bias = rand(&mut rng, oc);
        let mut out = vec![0.0; bt * oc];
        forward(&mut MatmulDispatch::Cpu, &mut out, &inp, &w, Some(&bias), bt, ic, oc).unwrap();
        for r in 0..bt {
            for o in 0..oc {
                let mut acc = bias[o];
                for i in 0..ic {
                    acc += inp[r * ic + i] * w[o * ic + i];
                }
                assert!((out[r * oc + o] - acc).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn npu_forward_matches_cpu_within_bf16() {
        let (bt, ic, oc) = (64, 64, 128);
        let mut rng = Rng::new(67);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let bias = rand(&mut rng, oc);
        let mut out_cpu = vec![0.0; bt * oc];
        forward(&mut MatmulDispatch::Cpu, &mut out_cpu, &inp, &w, Some(&bias), bt, ic, oc)
            .unwrap();
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let mut out_npu = vec![0.0; bt * oc];
        forward(
            &mut MatmulDispatch::Npu(&mut eng),
            &mut out_npu,
            &inp,
            &w,
            Some(&bias),
            bt,
            ic,
            oc,
        )
        .unwrap();
        for (x, y) in out_npu.iter().zip(&out_cpu) {
            assert!((x - y).abs() <= 0.06 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (bt, ic, oc) = (3, 4, 5);
        let mut rng = Rng::new(71);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let loss = |inp: &[f32], w: &[f32]| -> f32 {
            let mut out = vec![0.0; bt * oc];
            forward(&mut MatmulDispatch::Cpu, &mut out, inp, w, None, bt, ic, oc).unwrap();
            out.iter().zip(&dout).map(|(o, d)| o * d).sum()
        };

        let mut dinp = vec![0.0; bt * ic];
        let mut dw = vec![0.0; oc * ic];
        let mut dbias = vec![0.0; oc];
        backward(
            &mut MatmulDispatch::Cpu,
            &mut dinp,
            &mut dw,
            Some(&mut dbias),
            &dout,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();

        let h = 1e-3f32;
        for i in [0usize, bt * ic - 1, 5] {
            let mut p = inp.clone();
            p[i] += h;
            let mut m = inp.clone();
            m[i] -= h;
            let fd = (loss(&p, &w) - loss(&m, &w)) / (2.0 * h);
            assert!((fd - dinp[i]).abs() < 2e-2, "dinp[{i}] {fd} vs {}", dinp[i]);
        }
        for i in [0usize, oc * ic - 1] {
            let mut p = w.to_vec();
            p[i] += h;
            let mut m = w.to_vec();
            m[i] -= h;
            let fd = (loss(&inp, &p) - loss(&inp, &m)) / (2.0 * h);
            assert!((fd - dw[i]).abs() < 2e-2, "dw[{i}] {fd} vs {}", dw[i]);
        }
        // dbias = column sums of dout.
        for o in 0..oc {
            let expect: f32 = (0..bt).map(|r| dout[r * oc + o]).sum();
            assert!((dbias[o] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn npu_backward_matches_cpu_backward() {
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(73);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut dinp_c = vec![0.0; bt * ic];
        let mut dw_c = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Cpu, &mut dinp_c, &mut dw_c, None, &dout, &inp, &w, bt, ic, oc,
        )
        .unwrap();

        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let mut dinp_n = vec![0.0; bt * ic];
        let mut dw_n = vec![0.0; oc * ic];
        backward(
            &mut MatmulDispatch::Npu(&mut eng),
            &mut dinp_n,
            &mut dw_n,
            None,
            &dout,
            &inp,
            &w,
            bt,
            ic,
            oc,
        )
        .unwrap();

        // bf16 quantization noise: with K=64 zero-mean products, absolute
        // error up to ~sum|terms| * 2^-8; use an absolute-dominated bound.
        for (x, y) in dinp_n.iter().zip(&dinp_c) {
            assert!((x - y).abs() <= 0.12 + 0.02 * y.abs(), "{x} vs {y}");
        }
        for (x, y) in dw_n.iter().zip(&dw_c) {
            assert!((x - y).abs() <= 0.12 + 0.02 * y.abs(), "{x} vs {y}");
        }
    }

    #[test]
    fn deeper_ring_backward_bit_identical_to_serial_and_overlaps() {
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let (bt, ic, oc) = (64, 128, 64);
        let mut rng = Rng::new(79);
        let inp = rand(&mut rng, bt * ic);
        let w = rand(&mut rng, oc * ic);
        let dout = rand(&mut rng, bt * oc);

        let mut run = |depth: usize| {
            let mut sess = OffloadSession::new(
                SessionConfig {
                    depth: QueueDepth(depth),
                    ..Default::default()
                },
                &[],
            )
            .unwrap();
            let mut dinp = vec![0.0; bt * ic];
            let mut dw = vec![0.0; oc * ic];
            backward(
                &mut MatmulDispatch::Npu(&mut sess),
                &mut dinp,
                &mut dw,
                None,
                &dout,
                &inp,
                &w,
                bt,
                ic,
                oc,
            )
            .unwrap();
            let hidden = sess.pipeline.hidden_s();
            (dinp, dw, hidden)
        };
        let (dinp_s, dw_s, hidden_s) = run(1);
        let (dinp_p, dw_p, hidden_p) = run(2);
        assert_eq!(dinp_s, dinp_p, "ring depth must not change numerics");
        assert_eq!(dw_s, dw_p);
        assert_eq!(hidden_s, 0.0, "depth-1 (serial) schedule has no overlap");
        assert!(hidden_p > 0.0, "paired backward GEMMs must overlap");
    }
}
