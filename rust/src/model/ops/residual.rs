//! Residual connections (llm.c residual_forward / residual_backward).

/// out = a + b.
pub fn forward(out: &mut [f32], a: &[f32], b: &[f32]) {
    for i in 0..out.len() {
        out[i] = a[i] + b[i];
    }
}

/// Both branches receive the upstream gradient.
pub fn backward(da: &mut [f32], db: &mut [f32], dout: &[f32]) {
    for i in 0..dout.len() {
        da[i] += dout[i];
        db[i] += dout[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let mut out = [0.0f32; 2];
        forward(&mut out, &a, &b);
        assert_eq!(out, [11.0, 22.0]);
        let mut da = [0.0f32; 2];
        let mut db = [1.0f32; 2];
        backward(&mut da, &mut db, &out);
        assert_eq!(da, [11.0, 22.0]);
        assert_eq!(db, [12.0, 23.0]);
    }
}
