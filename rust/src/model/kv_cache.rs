//! Per-request KV-cache for decode (generation) on the offload stack.
//!
//! During decode only one new token enters the model per step, so the
//! attention inputs for positions `0..pos` never change — caching each
//! layer's K/V rows turns the per-token QKV/attention work into
//! matrix–vector shapes (M = 1 per request; M = R for a batched step)
//! instead of re-running the full context window. The cached rows are
//! copied verbatim from the QKV GEMM output, and the GEMM path computes
//! every output row independently of M (see `npu::execute_gemm`), so
//! decode against the cache stays bit-identical to a full-window
//! recompute forward.

use std::fmt;
use std::str::FromStr;

use super::acts::Activations;
use super::config::ModelConfig;

/// Whether the serving path uses the KV-cache (`on`, the default) or
/// falls back to per-token full-window recompute (`off`, the baseline
/// the bit-identity suite and `bench serve` compare against).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheMode {
    #[default]
    On,
    Off,
}

impl KvCacheMode {
    /// Is the KV-cached decode path active?
    pub fn enabled(self) -> bool {
        matches!(self, KvCacheMode::On)
    }
}

impl FromStr for KvCacheMode {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "on" => Ok(KvCacheMode::On),
            "off" => Ok(KvCacheMode::Off),
            other => Err(format!("unknown kv-cache setting '{other}' (expected on|off)")),
        }
    }
}

impl fmt::Display for KvCacheMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvCacheMode::On => write!(f, "on"),
            KvCacheMode::Off => write!(f, "off"),
        }
    }
}

/// Cached K/V rows for one generation request: (L, max_seq_len, C) per
/// tensor, filled left to right as positions are prefilled or decoded.
#[derive(Debug, Clone)]
pub struct KvCache {
    layers: usize,
    capacity: usize,
    channels: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// Empty cache sized for the model's full context window.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        let (l, t, c) = (cfg.num_layers, cfg.max_seq_len, cfg.channels);
        KvCache {
            layers: l,
            capacity: t,
            channels: c,
            len: 0,
            k: vec![0.0; l * t * c],
            v: vec![0.0; l * t * c],
        }
    }

    /// Number of cached positions (the furthest written position + 1).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions the cache can hold (the model context window).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Store one position's K/V rows for a layer. Idempotent: re-writing
    /// a position (a diverged decode step being re-recorded) overwrites
    /// with the same values and leaves `len` correct.
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(layer < self.layers && pos < self.capacity);
        let c = self.channels;
        let at = (layer * self.capacity + pos) * c;
        self.k[at..at + c].copy_from_slice(k_row);
        self.v[at..at + c].copy_from_slice(v_row);
        self.len = self.len.max(pos + 1);
    }

    /// The first `count` cached K rows of a layer, contiguous (count, C).
    pub fn k_rows(&self, layer: usize, count: usize) -> &[f32] {
        debug_assert!(count <= self.len);
        let c = self.channels;
        &self.k[layer * self.capacity * c..(layer * self.capacity + count) * c]
    }

    /// The first `count` cached V rows of a layer, contiguous (count, C).
    pub fn v_rows(&self, layer: usize, count: usize) -> &[f32] {
        debug_assert!(count <= self.len);
        let c = self.channels;
        &self.v[layer * self.capacity * c..(layer * self.capacity + count) * c]
    }

    /// Seed the cache from a prefill forward's activation arena (batch
    /// size 1): copy each layer's K/V rows for positions `0..n_pos` out
    /// of the packed (L,1,T,3C) `qkv` activations.
    pub fn load_prefill(&mut self, acts: &Activations, n_pos: usize) {
        assert_eq!(acts.b, 1, "prefill caches are per request");
        assert!(n_pos <= acts.t);
        let c = self.channels;
        for l in 0..self.layers {
            for pos in 0..n_pos {
                let row = (l * acts.t + pos) * 3 * c;
                let k = &acts.qkv[row + c..row + 2 * c];
                let v = &acts.qkv[row + 2 * c..row + 3 * c];
                self.write(l, pos, k, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_cache_mode_parses_cli_forms() {
        assert_eq!("on".parse::<KvCacheMode>().unwrap(), KvCacheMode::On);
        assert_eq!("off".parse::<KvCacheMode>().unwrap(), KvCacheMode::Off);
        assert!("none".parse::<KvCacheMode>().is_err());
        assert_eq!(KvCacheMode::default(), KvCacheMode::On);
        assert_eq!(KvCacheMode::On.to_string(), "on");
        assert!(KvCacheMode::On.enabled());
        assert!(!KvCacheMode::Off.enabled());
    }

    #[test]
    fn write_then_read_rows_round_trip() {
        let cfg = ModelConfig::d2();
        let c = cfg.channels;
        let mut kv = KvCache::new(&cfg);
        assert!(kv.is_empty());
        let k0 = vec![1.0f32; c];
        let v0 = vec![2.0f32; c];
        let k1 = vec![3.0f32; c];
        let v1 = vec![4.0f32; c];
        for l in 0..cfg.num_layers {
            kv.write(l, 0, &k0, &v0);
            kv.write(l, 1, &k1, &v1);
        }
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.capacity(), cfg.max_seq_len);
        let k = kv.k_rows(1, 2);
        assert_eq!(&k[..c], &k0[..]);
        assert_eq!(&k[c..], &k1[..]);
        let v = kv.v_rows(0, 2);
        assert_eq!(&v[..c], &v0[..]);
        assert_eq!(&v[c..], &v1[..]);
        // Idempotent re-write (the divergence re-record path).
        kv.write(0, 1, &k1, &v1);
        assert_eq!(kv.len(), 2);
    }

    #[test]
    fn load_prefill_copies_layer_rows_from_packed_qkv() {
        let cfg = ModelConfig::d2();
        let (c, t) = (cfg.channels, 4);
        let mut acts = Activations::new(&cfg, 1, t);
        for (i, x) in acts.qkv.iter_mut().enumerate() {
            *x = i as f32;
        }
        let mut kv = KvCache::new(&cfg);
        kv.load_prefill(&acts, 3);
        assert_eq!(kv.len(), 3);
        for l in 0..cfg.num_layers {
            for pos in 0..3 {
                let row = (l * t + pos) * 3 * c;
                assert_eq!(
                    kv.k_rows(l, 3)[pos * c..(pos + 1) * c],
                    acts.qkv[row + c..row + 2 * c]
                );
                assert_eq!(
                    kv.v_rows(l, 3)[pos * c..(pos + 1) * c],
                    acts.qkv[row + 2 * c..row + 3 * c]
                );
            }
        }
    }
}
