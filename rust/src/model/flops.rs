//! FLOP accounting per op (regenerates the paper's Figure 2 numbers).
//!
//! Figure 2 annotates the GPT-2 computation graph with per-op FLOP counts
//! for the forward pass (backward ≈ 2×). The paper's epoch figure —
//! "Each epoch consists of 197 GFLOP" — is the fwd+bwd total at B=4, T=64.

use super::config::ModelConfig;

/// FLOPs of one op category over a full forward pass.
#[derive(Debug, Clone)]
pub struct OpFlops {
    pub op: &'static str,
    pub forward: u64,
    pub backward: u64,
}

/// Per-op forward/backward FLOP table for a batch shape.
pub fn table(cfg: &ModelConfig, b: usize, t: usize) -> Vec<OpFlops> {
    let c = cfg.channels as u64;
    let l = cfg.num_layers as u64;
    let nh = cfg.num_heads as u64;
    let vp = cfg.padded_vocab_size as u64;
    let bt = (b * t) as u64;
    let tt = t as u64;

    // encoder: one add per element.
    let encoder = bt * c;
    // layernorm: ~5 flops/element, 2L+1 instances.
    let layernorm = (2 * l + 1) * 5 * bt * c;
    // matmuls (2*M*K*N each): qkv + attproj + fc + fcproj per layer + head.
    let matmul = l * (2 * bt * c * 3 * c + 2 * bt * c * c + 2 * bt * c * 4 * c + 2 * bt * 4 * c * c)
        + 2 * bt * c * vp;
    // attention: qk^T and att*v are B*NH*T*T*HS MACs each (causal halves
    // it; Figure 2 counts the full square, we count causal).
    let hs = c / nh;
    let attention =
        l * (2 * (b as u64) * nh * tt * (tt + 1) / 2 * hs * 2
            + 5 * (b as u64) * nh * tt * (tt + 1) / 2);
    // gelu: ~8 flops/element on 4C.
    let gelu = l * 8 * bt * 4 * c;
    // residuals: 2L adds over BTC.
    let residual = 2 * l * bt * c;
    // classifier: softmax ~4 flops/element over Vp + loss.
    let classifier = 4 * bt * vp;

    vec![
        OpFlops { op: "encoder", forward: encoder, backward: 2 * encoder },
        OpFlops { op: "layernorm", forward: layernorm, backward: 2 * layernorm },
        OpFlops { op: "matmul", forward: matmul, backward: 2 * matmul },
        OpFlops { op: "attention", forward: attention, backward: 2 * attention },
        OpFlops { op: "gelu", forward: gelu, backward: 2 * gelu },
        OpFlops { op: "residual", forward: residual, backward: 2 * residual },
        OpFlops { op: "softmax+ce", forward: classifier, backward: classifier },
    ]
}

/// Total fwd+bwd FLOPs of one training step.
pub fn total_per_step(cfg: &ModelConfig, b: usize, t: usize) -> u64 {
    table(cfg, b, t)
        .iter()
        .map(|o| o.forward + o.backward)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_epoch_is_about_197_gflop() {
        // Paper section VII: one epoch (one step at B=4, T=64) = 197 GFLOP.
        let total = total_per_step(&ModelConfig::d12(), 4, 64);
        let gflop = total as f64 / 1e9;
        assert!(
            (170.0..215.0).contains(&gflop),
            "epoch FLOPs {gflop} GFLOP should be near the paper's 197"
        );
    }

    #[test]
    fn matmul_dominates() {
        let t = table(&ModelConfig::d12(), 4, 64);
        let matmul = t.iter().find(|o| o.op == "matmul").unwrap().forward;
        let rest: u64 = t.iter().filter(|o| o.op != "matmul").map(|o| o.forward).sum();
        assert!(matmul > 5 * rest, "matmul {matmul} vs rest {rest}");
    }

    #[test]
    fn matmul_flops_match_gemm_site_accounting() {
        use crate::gemm::sizes::{total_gemm_flops, ModelDims};
        let t = table(&ModelConfig::d12(), 4, 64);
        let matmul = t.iter().find(|o| o.op == "matmul").unwrap();
        let sites = total_gemm_flops(&ModelDims::gpt2_124m());
        assert_eq!(matmul.forward + matmul.backward, sites);
    }
}
