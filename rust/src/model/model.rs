//! The GPT-2 model object: llm.c's gpt2_forward / gpt2_backward /
//! gpt2_update, with per-op wallclock accounting (the paper's Figure 8
//! splits epoch time by operation).
//!
//! Every matmul flows through the [`MatmulDispatch`] seam: the CPU loop
//! nest, an eager offload session, — with `MatmulDispatch::Plan` — a
//! recorded [`crate::coordinator::plan::StepPlan`] that defers the whole
//! step's offload schedule to `OffloadSession::execute`, or — with
//! `MatmulDispatch::Replay` — a cache-hit re-run of a frozen plan whose
//! schedule `OffloadSession::finish_replay` charges in one pass.

use crate::coordinator::plan::{FusedEpilogue, PlanOpKind};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::timer::StageTimer;

use super::acts::{ActGrads, Activations};
use super::config::ModelConfig;
use super::ops::adamw::AdamW;
use super::ops::matmul::MatmulDispatch;
use super::ops::{attention, classifier, encoder, gelu, layernorm, matmul, residual};
use super::params::ParamTensors;

/// Figure-8 op categories.
pub const OP_ENCODER: &str = "encoder";
pub const OP_LAYERNORM: &str = "layernorm";
pub const OP_MATMUL: &str = "matmul";
pub const OP_ATTENTION: &str = "attention";
pub const OP_GELU: &str = "gelu";
pub const OP_RESIDUAL: &str = "residual";
pub const OP_CLASSIFIER: &str = "softmax+ce";
pub const OP_ADAMW: &str = "adamw";

/// All op categories in reporting order.
pub const OPS: [&str; 8] = [
    OP_ENCODER,
    OP_LAYERNORM,
    OP_MATMUL,
    OP_ATTENTION,
    OP_GELU,
    OP_RESIDUAL,
    OP_CLASSIFIER,
    OP_ADAMW,
];

/// Wallclock per op category.
pub type OpTimers = StageTimer;

/// The model: parameters, optimizer state, gradients, activations.
pub struct Gpt2Model {
    pub cfg: ModelConfig,
    pub params: ParamTensors,
    pub grads: ParamTensors,
    pub m: ParamTensors,
    pub v: ParamTensors,
    pub acts: Option<Activations>,
    act_grads: Option<ActGrads>,
    /// Cached batch inputs of the last forward.
    tokens: Vec<i32>,
    targets: Vec<i32>,
    pub step: u32,
    /// Per-op wallclock (Figure 8).
    pub op_timers: OpTimers,
    /// Block-level offload: record the transformer's non-GEMM sites
    /// (layernorm, softmax) as elementwise plan ops and chain their
    /// consumer GEMMs device-resident, with the fc matmul's gelu fused
    /// as an epilogue. Off by default — the paper's GEMM-only plan; the
    /// flag changes only the *modeled* schedule (plan signatures
    /// diverge, so cached GEMM-only and block-offloaded steps coexist),
    /// never the numerics, which stay the host-op baseline bit-for-bit.
    pub block_offload: bool,
}

impl Gpt2Model {
    /// Random-initialized model.
    pub fn new(cfg: ModelConfig, seed: u64) -> Gpt2Model {
        let mut rng = Rng::new(seed);
        Gpt2Model {
            cfg,
            params: ParamTensors::random_init(&cfg, &mut rng),
            grads: ParamTensors::zeros(&cfg),
            m: ParamTensors::zeros(&cfg),
            v: ParamTensors::zeros(&cfg),
            acts: None,
            act_grads: None,
            tokens: Vec::new(),
            targets: Vec::new(),
            step: 0,
            op_timers: StageTimer::new(),
            block_offload: false,
        }
    }

    /// Model around existing parameters (e.g. loaded from a checkpoint).
    pub fn with_params(cfg: ModelConfig, params: ParamTensors) -> Gpt2Model {
        Gpt2Model {
            cfg,
            params,
            grads: ParamTensors::zeros(&cfg),
            m: ParamTensors::zeros(&cfg),
            v: ParamTensors::zeros(&cfg),
            acts: None,
            act_grads: None,
            tokens: Vec::new(),
            targets: Vec::new(),
            step: 0,
            op_timers: StageTimer::new(),
            block_offload: false,
        }
    }

    fn ensure_arenas(&mut self, b: usize, t: usize) {
        let need = match &self.acts {
            Some(a) => a.b != b || a.t != t,
            None => true,
        };
        if need {
            self.acts = Some(Activations::new(&self.cfg, b, t));
            self.act_grads = Some(ActGrads::new(&self.cfg, b, t));
        }
    }

    /// Forward pass; with targets, fills probs/losses and returns the mean
    /// loss (llm.c gpt2_forward).
    pub fn forward(
        &mut self,
        dispatch: &mut MatmulDispatch,
        tokens: &[i32],
        targets: Option<&[i32]>,
        b: usize,
        t: usize,
    ) -> Result<Option<f32>> {
        assert_eq!(tokens.len(), b * t);
        let c = self.cfg.channels;
        let nh = self.cfg.num_heads;
        let vp = self.cfg.padded_vocab_size;
        let bt = b * t;
        self.ensure_arenas(b, t);
        self.tokens = tokens.to_vec();
        // Block offload: layernorm/softmax sites become elementwise plan
        // ops and their consumer GEMMs chain device-resident. Host
        // numerics below are untouched either way.
        let block = self.block_offload;
        let acts = self.acts.as_mut().unwrap();
        let timers = &mut self.op_timers;
        let p = &self.params;

        timers.time(OP_ENCODER, || {
            encoder::forward(
                &mut acts.encoded,
                tokens,
                p.tensor("wte"),
                p.tensor("wpe"),
                b,
                t,
                c,
            )
        });

        for l in 0..self.cfg.num_layers {
            let residual_in: Vec<f32> = if l == 0 {
                acts.encoded.clone()
            } else {
                acts.residual3[(l - 1) * bt * c..l * bt * c].to_vec()
            };

            timers.time(OP_LAYERNORM, || {
                layernorm::forward(
                    &mut acts.ln1[l * bt * c..(l + 1) * bt * c],
                    &mut acts.ln1_mean[l * bt..(l + 1) * bt],
                    &mut acts.ln1_rstd[l * bt..(l + 1) * bt],
                    &residual_in,
                    p.layer("ln1w", l),
                    p.layer("ln1b", l),
                    bt,
                    c,
                )
            });
            if block {
                // ln1's output stays resident for the QKV matmul; its
                // own input is the host-side residual stream.
                matmul::elementwise(dispatch, PlanOpKind::LayerNorm, bt, c, false)?;
            }
            {
                let out = &mut acts.qkv[l * bt * 3 * c..(l + 1) * bt * 3 * c];
                let inp = &acts.ln1[l * bt * c..(l + 1) * bt * c];
                let t0 = std::time::Instant::now();
                matmul::forward_hinted(
                    dispatch,
                    out,
                    inp,
                    p.layer("qkvw", l),
                    Some(p.layer("qkvb", l)),
                    bt,
                    c,
                    3 * c,
                    FusedEpilogue::None,
                    block,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }
            timers.time(OP_ATTENTION, || {
                attention::forward(
                    &mut acts.atty[l * bt * c..(l + 1) * bt * c],
                    &mut acts.preatt[l * b * nh * t * t..(l + 1) * b * nh * t * t],
                    &mut acts.att[l * b * nh * t * t..(l + 1) * b * nh * t * t],
                    &acts.qkv[l * bt * 3 * c..(l + 1) * bt * 3 * c],
                    b,
                    t,
                    c,
                    nh,
                )
            });
            {
                let t0 = std::time::Instant::now();
                let out = &mut acts.attproj[l * bt * c..(l + 1) * bt * c];
                let inp = &acts.atty[l * bt * c..(l + 1) * bt * c];
                matmul::forward(
                    dispatch,
                    out,
                    inp,
                    p.layer("attprojw", l),
                    Some(p.layer("attprojb", l)),
                    bt,
                    c,
                    c,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }
            timers.time(OP_RESIDUAL, || {
                let (a, bslice) = (
                    &residual_in,
                    &acts.attproj[l * bt * c..(l + 1) * bt * c],
                );
                residual::forward(
                    &mut acts.residual2[l * bt * c..(l + 1) * bt * c],
                    a,
                    bslice,
                )
            });
            {
                // Split borrows: ln2 reads residual2.
                let (res2_all, ln2_all) = (&acts.residual2, &mut acts.ln2);
                timers.time(OP_LAYERNORM, || {
                    layernorm::forward(
                        &mut ln2_all[l * bt * c..(l + 1) * bt * c],
                        &mut acts.ln2_mean[l * bt..(l + 1) * bt],
                        &mut acts.ln2_rstd[l * bt..(l + 1) * bt],
                        &res2_all[l * bt * c..(l + 1) * bt * c],
                        p.layer("ln2w", l),
                        p.layer("ln2b", l),
                        bt,
                        c,
                    )
                });
            }
            if block {
                // ln2's output feeds the fc matmul device-resident.
                matmul::elementwise(dispatch, PlanOpKind::LayerNorm, bt, c, false)?;
            }
            {
                let t0 = std::time::Instant::now();
                // With block offload the gelu rides the fc matmul as a
                // fused epilogue — no separate elementwise op, and the
                // fused output stays resident for fcproj.
                matmul::forward_hinted(
                    dispatch,
                    &mut acts.fch[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                    &acts.ln2[l * bt * c..(l + 1) * bt * c],
                    p.layer("fcw", l),
                    Some(p.layer("fcb", l)),
                    bt,
                    c,
                    4 * c,
                    if block { FusedEpilogue::Gelu } else { FusedEpilogue::None },
                    block,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }
            timers.time(OP_GELU, || {
                gelu::forward(
                    &mut acts.fch_gelu[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                    &acts.fch[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                )
            });
            {
                let t0 = std::time::Instant::now();
                matmul::forward_hinted(
                    dispatch,
                    &mut acts.fcproj[l * bt * c..(l + 1) * bt * c],
                    &acts.fch_gelu[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                    p.layer("fcprojw", l),
                    Some(p.layer("fcprojb", l)),
                    bt,
                    4 * c,
                    c,
                    FusedEpilogue::None,
                    block,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }
            timers.time(OP_RESIDUAL, || {
                let fcproj = &acts.fcproj[l * bt * c..(l + 1) * bt * c];
                let res2 = &acts.residual2[l * bt * c..(l + 1) * bt * c];
                let mut out = vec![0.0f32; bt * c];
                residual::forward(&mut out, res2, fcproj);
                acts.residual3[l * bt * c..(l + 1) * bt * c].copy_from_slice(&out);
            });
        }

        let l_last = self.cfg.num_layers - 1;
        timers.time(OP_LAYERNORM, || {
            layernorm::forward(
                &mut acts.lnf,
                &mut acts.lnf_mean,
                &mut acts.lnf_rstd,
                &acts.residual3[l_last * bt * c..(l_last + 1) * bt * c],
                p.tensor("lnfw"),
                p.tensor("lnfb"),
                bt,
                c,
            )
        });
        if block {
            // lnf's output stays resident for the lm-head matmul.
            matmul::elementwise(dispatch, PlanOpKind::LayerNorm, bt, c, false)?;
        }
        {
            let t0 = std::time::Instant::now();
            // LM head: logits = lnf · wteᵀ (weight sharing, no bias).
            matmul::forward_hinted(
                dispatch,
                &mut acts.logits,
                &acts.lnf,
                p.tensor("wte"),
                None,
                bt,
                c,
                vp,
                FusedEpilogue::None,
                block,
            )?;
            timers.add(OP_MATMUL, t0.elapsed());
        }

        if let Some(targets) = targets {
            assert_eq!(targets.len(), bt);
            self.targets = targets.to_vec();
            if block {
                // Softmax over the logits the lm-head left resident —
                // the only elementwise site whose input never
                // round-trips; the probabilities spill to host for the
                // loss and backward.
                matmul::elementwise(dispatch, PlanOpKind::Softmax, bt, vp, true)?;
            }
            let loss = timers.time(OP_CLASSIFIER, || {
                classifier::forward(
                    &mut acts.probs,
                    &mut acts.losses,
                    &acts.logits,
                    targets,
                    bt,
                    vp,
                );
                acts.mean_loss()
            });
            Ok(Some(loss))
        } else {
            self.targets.clear();
            Ok(None)
        }
    }

    /// Zero parameter gradients (llm.c gpt2_zero_grad).
    pub fn zero_grad(&mut self) {
        self.grads.as_mut_slice().fill(0.0);
    }

    /// Backward pass (llm.c gpt2_backward). Requires a prior forward with
    /// targets.
    pub fn backward(&mut self, dispatch: &mut MatmulDispatch) -> Result<()> {
        let c = self.cfg.channels;
        let nh = self.cfg.num_heads;
        let vp = self.cfg.padded_vocab_size;
        let acts = self.acts.as_ref().expect("forward first");
        let (b, t) = (acts.b, acts.t);
        let bt = b * t;
        assert!(!self.targets.is_empty(), "backward requires targets");

        // Take arenas out to sidestep aliasing with &self.
        let mut g = self.act_grads.take().expect("forward first");
        g.zero();
        let acts = self.acts.as_ref().unwrap();
        let p = &self.params;
        let grads = &mut self.grads;
        let timers = &mut self.op_timers;

        timers.time(OP_CLASSIFIER, || {
            classifier::backward(&mut g.d_logits, &acts.probs, &self.targets, bt, vp)
        });

        // LM head backward: dlnf = dlogits · wte ; dwte += dlogitsᵀ · lnf.
        {
            let t0 = std::time::Instant::now();
            let dw_off = grads.tensor_range("wte")?.0;
            matmul::backward(
                dispatch,
                &mut g.d_lnf,
                grads.tensor_mut("wte"),
                dw_off,
                None,
                // d_logits is written once per step (classifier
                // backward, above) — step-stable, so the background
                // executor borrows the ~BT·Vp dout zero-copy.
                &g.d_logits,
                true,
                &acts.lnf,
                p.tensor("wte"),
                bt,
                c,
                vp,
            )?;
            timers.add(OP_MATMUL, t0.elapsed());
        }

        let l_last = self.cfg.num_layers - 1;
        // d_residual3 of the last layer accumulates from lnf backward.
        timers.time(OP_LAYERNORM, || {
            let (dlnfw, dlnfb) = grads.pair_mut("lnfw", None, "lnfb", None);
            layernorm::backward(
                &mut g.d_residual3,
                dlnfw,
                dlnfb,
                &g.d_lnf,
                &acts.residual3[l_last * bt * c..(l_last + 1) * bt * c],
                p.tensor("lnfw"),
                &acts.lnf_mean,
                &acts.lnf_rstd,
                bt,
                c,
            )
        });

        for l in (0..self.cfg.num_layers).rev() {
            let residual_in: &[f32] = if l == 0 {
                &acts.encoded
            } else {
                &acts.residual3[(l - 1) * bt * c..l * bt * c]
            };
            // Parity slot for this layer's deferred-dW dout scratches:
            // the buffer a background dW job borrowed is not rewritten
            // until two layers later, by which time a younger layer's
            // in-call dinp wait has drained it (FIFO executor).
            let pi = l % 2;

            // residual3 = residual2 + fcproj.
            g.d_residual2.fill(0.0);
            g.d_fcproj[pi].fill(0.0);
            timers.time(OP_RESIDUAL, || {
                residual::backward(&mut g.d_residual2, &mut g.d_fcproj[pi], &g.d_residual3)
            });

            // fcproj backward.
            g.d_fch_gelu.fill(0.0);
            {
                let t0 = std::time::Instant::now();
                let dw_off = grads.layer_range("fcprojw", l)?.0;
                let (dw, db) = grads.pair_mut("fcprojw", Some(l), "fcprojb", Some(l));
                matmul::backward(
                    dispatch,
                    &mut g.d_fch_gelu,
                    dw,
                    dw_off,
                    Some(db),
                    &g.d_fcproj[pi],
                    true,
                    &acts.fch_gelu[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                    p.layer("fcprojw", l),
                    bt,
                    4 * c,
                    c,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }

            g.d_fch[pi].fill(0.0);
            timers.time(OP_GELU, || {
                gelu::backward(
                    &mut g.d_fch[pi],
                    &acts.fch[l * bt * 4 * c..(l + 1) * bt * 4 * c],
                    &g.d_fch_gelu,
                )
            });

            // fc backward.
            g.d_ln2.fill(0.0);
            {
                let t0 = std::time::Instant::now();
                let dw_off = grads.layer_range("fcw", l)?.0;
                let (dw, db) = grads.pair_mut("fcw", Some(l), "fcb", Some(l));
                matmul::backward(
                    dispatch,
                    &mut g.d_ln2,
                    dw,
                    dw_off,
                    Some(db),
                    &g.d_fch[pi],
                    true,
                    &acts.ln2[l * bt * c..(l + 1) * bt * c],
                    p.layer("fcw", l),
                    bt,
                    c,
                    4 * c,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }

            // ln2 backward accumulates into d_residual2.
            timers.time(OP_LAYERNORM, || {
                let (dw, db) = grads.pair_mut("ln2w", Some(l), "ln2b", Some(l));
                layernorm::backward(
                    &mut g.d_residual2,
                    dw,
                    db,
                    &g.d_ln2,
                    &acts.residual2[l * bt * c..(l + 1) * bt * c],
                    p.layer("ln2w", l),
                    &acts.ln2_mean[l * bt..(l + 1) * bt],
                    &acts.ln2_rstd[l * bt..(l + 1) * bt],
                    bt,
                    c,
                )
            });

            // residual2 = residual_in + attproj.
            g.d_residual3.fill(0.0); // reuse as d(residual_in)
            g.d_attproj[pi].fill(0.0);
            timers.time(OP_RESIDUAL, || {
                residual::backward(&mut g.d_residual3, &mut g.d_attproj[pi], &g.d_residual2)
            });

            // attproj backward.
            g.d_atty.fill(0.0);
            {
                let t0 = std::time::Instant::now();
                let dw_off = grads.layer_range("attprojw", l)?.0;
                let (dw, db) = grads.pair_mut("attprojw", Some(l), "attprojb", Some(l));
                matmul::backward(
                    dispatch,
                    &mut g.d_atty,
                    dw,
                    dw_off,
                    Some(db),
                    &g.d_attproj[pi],
                    true,
                    &acts.atty[l * bt * c..(l + 1) * bt * c],
                    p.layer("attprojw", l),
                    bt,
                    c,
                    c,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }

            // attention backward.
            g.d_qkv[pi].fill(0.0);
            timers.time(OP_ATTENTION, || {
                attention::backward(
                    &mut g.d_qkv[pi],
                    &mut g.d_preatt,
                    &mut g.d_att,
                    &g.d_atty,
                    &acts.qkv[l * bt * 3 * c..(l + 1) * bt * 3 * c],
                    &acts.att[l * b * nh * t * t..(l + 1) * b * nh * t * t],
                    b,
                    t,
                    c,
                    nh,
                )
            });

            // qkv matmul backward.
            g.d_ln1.fill(0.0);
            {
                let t0 = std::time::Instant::now();
                let dw_off = grads.layer_range("qkvw", l)?.0;
                let (dw, db) = grads.pair_mut("qkvw", Some(l), "qkvb", Some(l));
                matmul::backward(
                    dispatch,
                    &mut g.d_ln1,
                    dw,
                    dw_off,
                    Some(db),
                    &g.d_qkv[pi],
                    true,
                    &acts.ln1[l * bt * c..(l + 1) * bt * c],
                    p.layer("qkvw", l),
                    bt,
                    c,
                    3 * c,
                )?;
                timers.add(OP_MATMUL, t0.elapsed());
            }

            // ln1 backward accumulates into d(residual_in).
            timers.time(OP_LAYERNORM, || {
                let (dw, db) = grads.pair_mut("ln1w", Some(l), "ln1b", Some(l));
                layernorm::backward(
                    &mut g.d_residual3,
                    dw,
                    db,
                    &g.d_ln1,
                    residual_in,
                    p.layer("ln1w", l),
                    &acts.ln1_mean[l * bt..(l + 1) * bt],
                    &acts.ln1_rstd[l * bt..(l + 1) * bt],
                    bt,
                    c,
                )
            });
            // d_residual3 now holds the gradient flowing to the previous
            // layer's residual3 (or the encoder at l == 0).
        }

        // Encoder backward.
        timers.time(OP_ENCODER, || {
            let (dwte, dwpe_range) = {
                // split mutable borrows by raw ranges
                let (wte_off, wte_len) = grads.tensor_range("wte").unwrap();
                let (wpe_off, wpe_len) = grads.tensor_range("wpe").unwrap();
                let data = grads.as_mut_slice();
                // SAFETY: disjoint, verified by tensor layout.
                let dwte = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr().add(wte_off), wte_len)
                };
                let dwpe = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr().add(wpe_off), wpe_len)
                };
                (dwte, dwpe)
            };
            encoder::backward(dwte, dwpe_range, &g.d_residual3, &self.tokens, b, t, c);
        });

        self.act_grads = Some(g);
        Ok(())
    }

    /// Optimizer step (llm.c gpt2_update). Returns the pre-clip grad norm.
    pub fn update(&mut self, opt: &AdamW) -> f32 {
        self.step += 1;
        let step = self.step;
        let timers = &mut self.op_timers;
        timers.time(OP_ADAMW, || {
            opt.step(
                self.params.as_mut_slice(),
                self.grads.as_slice(),
                self.m.as_mut_slice(),
                self.v.as_mut_slice(),
                step,
            )
        })
    }

    /// Greedy/temperature sampling of the next token from the last
    /// position's logits (generation).
    pub fn sample_next(&self, rng: &mut Rng, temperature: f32) -> usize {
        let acts = self.acts.as_ref().expect("forward first");
        let vp = self.cfg.padded_vocab_size;
        let bt = acts.b * acts.t;
        let logits = &acts.logits[(bt - 1) * vp..bt * vp];
        sample_logits(logits, self.cfg.vocab_size, rng, temperature)
    }
}

/// Greedy/temperature sampling from one position's logits row over the
/// real vocab `v`. Shared by [`Gpt2Model::sample_next`] and the serving
/// engine so every generation path draws tokens with the same float op
/// sequence (a precondition of the decode bit-identity suite).
pub fn sample_logits(logits: &[f32], v: usize, rng: &mut Rng, temperature: f32) -> usize {
    if temperature <= 0.0 {
        // argmax over the real vocab
        let mut best = 0;
        for i in 1..v {
            if logits[i] > logits[best] {
                best = i;
            }
        }
        return best;
    }
    let maxv = logits[..v].iter().copied().fold(f32::MIN, f32::max);
    let mut probs: Vec<f32> = logits[..v]
        .iter()
        .map(|&x| ((x - maxv) / temperature).exp())
        .collect();
    let sum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= sum;
    }
    rng.sample_discrete(&probs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_batch(cfg: &ModelConfig, b: usize, t: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        (tokens, targets)
    }

    #[test]
    fn initial_loss_is_log_vocab() {
        let cfg = ModelConfig::d2();
        let mut model = Gpt2Model::new(cfg, 42);
        let (tokens, targets) = tiny_batch(&cfg, 2, 16, 1);
        let loss = model
            .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 2, 16)
            .unwrap()
            .unwrap();
        let expect = (cfg.padded_vocab_size as f32).ln();
        assert!((loss - expect).abs() < 0.3, "loss {loss} vs ln(V) {expect}");
    }

    #[test]
    fn loss_decreases_under_training() {
        let cfg = ModelConfig::d2();
        let mut model = Gpt2Model::new(cfg, 42);
        let (tokens, targets) = tiny_batch(&cfg, 2, 16, 2);
        let opt = AdamW {
            lr: 1e-3,
            ..Default::default()
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..8 {
            let loss = model
                .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            if i == 0 {
                first = loss;
            }
            last = loss;
            model.zero_grad();
            model.backward(&mut MatmulDispatch::Cpu).unwrap();
            model.update(&opt);
        }
        assert!(
            last < first - 0.5,
            "loss should drop by >0.5 overfitting one batch: {first} -> {last}"
        );
    }

    #[test]
    fn grads_match_finite_differences_spot_check() {
        let cfg = ModelConfig::d2();
        let mut model = Gpt2Model::new(cfg, 7);
        let (tokens, targets) = tiny_batch(&cfg, 1, 8, 3);

        model
            .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 1, 8)
            .unwrap();
        model.zero_grad();
        model.backward(&mut MatmulDispatch::Cpu).unwrap();

        // Spot-check a few parameters across tensors.
        let h = 1e-2f32;
        for (name, idx) in [("wte", 10usize), ("qkvw", 123), ("fcw", 77), ("lnfw", 3)] {
            let (off, _) = model.params.tensor_range(name).unwrap();
            let flat = off + idx;
            let analytic = model.grads.as_slice()[flat];

            let orig = model.params.as_slice()[flat];
            model.params.as_mut_slice()[flat] = orig + h;
            let lp = model
                .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 1, 8)
                .unwrap()
                .unwrap();
            model.params.as_mut_slice()[flat] = orig - h;
            let lm = model
                .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 1, 8)
                .unwrap()
                .unwrap();
            model.params.as_mut_slice()[flat] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - analytic).abs() < 2e-2_f32.max(0.2 * fd.abs()),
                "{name}[{idx}]: fd {fd} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn npu_dispatch_trains_like_cpu() {
        use crate::coordinator::engine::{EngineConfig, GemmOffloadEngine};
        let cfg = ModelConfig::d2();
        let (tokens, targets) = tiny_batch(&cfg, 2, 16, 5);

        let mut cpu_model = Gpt2Model::new(cfg, 99);
        let mut npu_model = Gpt2Model::new(cfg, 99);
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let opt = AdamW::default();

        for _ in 0..3 {
            let lc = cpu_model
                .forward(&mut MatmulDispatch::Cpu, &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            cpu_model.zero_grad();
            cpu_model.backward(&mut MatmulDispatch::Cpu).unwrap();
            cpu_model.update(&opt);

            let ln = npu_model
                .forward(&mut MatmulDispatch::Npu(&mut eng), &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            npu_model.zero_grad();
            npu_model.backward(&mut MatmulDispatch::Npu(&mut eng)).unwrap();
            npu_model.update(&opt);

            // bf16 GEMMs: small divergence, same trajectory (paper VII-A).
            assert!((lc - ln).abs() < 0.05 * lc.abs().max(1.0), "loss {lc} vs {ln}");
        }
        assert!(eng.invocations > 0, "NPU path must actually offload");
    }

    #[test]
    fn plan_dispatch_records_every_gemm_site_and_matches_eager() {
        use crate::coordinator::plan::StepPlan;
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let (tokens, targets) = tiny_batch(&cfg, 2, 16, 13);

        let mut eager_model = Gpt2Model::new(cfg, 55);
        let mut eager_sess = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
        let le = eager_model
            .forward(
                &mut MatmulDispatch::Npu(&mut eager_sess),
                &tokens,
                Some(&targets),
                2,
                16,
            )
            .unwrap()
            .unwrap();
        eager_model.zero_grad();
        eager_model
            .backward(&mut MatmulDispatch::Npu(&mut eager_sess))
            .unwrap();

        let mut plan_model = Gpt2Model::new(cfg, 55);
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = StepPlan::new();
        let lp = {
            let mut d = MatmulDispatch::Plan {
                session: &mut sess,
                plan: &mut plan,
            };
            let lp = plan_model
                .forward(&mut d, &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            plan_model.zero_grad();
            plan_model.backward(&mut d).unwrap();
            lp
        };
        assert_eq!(le, lp, "recording must not change the loss");
        assert_eq!(
            plan_model.grads.as_slice(),
            eager_model.grads.as_slice(),
            "recording must not change gradients"
        );
        // d2 = 2 layers: forward 4 per layer + lm_head = 9 GEMMs, backward
        // records a (dinp, dW) pair per site = 18 more.
        assert_eq!(plan.len(), 27, "every GEMM site must be recorded");
        let report = sess.execute(&mut plan).unwrap();
        assert_eq!(report.stats.len(), 27);
        assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
    }

    #[test]
    fn block_offload_records_elementwise_sites_and_keeps_numerics() {
        use crate::coordinator::plan::StepPlan;
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let (tokens, targets) = tiny_batch(&cfg, 2, 16, 17);

        // GEMM-only baseline step (block offload off).
        let mut base_model = Gpt2Model::new(cfg, 55);
        let mut base_sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut base_plan = StepPlan::new();
        let lb = {
            let mut d = MatmulDispatch::Plan {
                session: &mut base_sess,
                plan: &mut base_plan,
            };
            let lb = base_model
                .forward(&mut d, &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            base_model.zero_grad();
            base_model.backward(&mut d).unwrap();
            lb
        };
        assert_eq!(base_plan.len(), 27, "GEMM-only contract unchanged");
        base_sess.execute(&mut base_plan).unwrap();

        // Block-offloaded step on the same weights and batch.
        let mut model = Gpt2Model::new(cfg, 55);
        model.block_offload = true;
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = StepPlan::new();
        let lp = {
            let mut d = MatmulDispatch::Plan {
                session: &mut sess,
                plan: &mut plan,
            };
            let lp = model
                .forward(&mut d, &tokens, Some(&targets), 2, 16)
                .unwrap()
                .unwrap();
            model.zero_grad();
            model.backward(&mut d).unwrap();
            lp
        };
        // 27 GEMMs + per layer (ln1, ln2) + lnf + softmax = 33 at d2.
        assert_eq!(plan.len(), 33, "every elementwise site must be recorded");
        assert_eq!(lb, lp, "block offload must not change the loss");
        assert_eq!(
            model.grads.as_slice(),
            base_model.grads.as_slice(),
            "block offload must not change gradients"
        );
        let report = sess.execute(&mut plan).unwrap();
        assert_eq!(report.stats.len(), 33);
        // 6 recorded elementwise ops + 2 fused-gelu fc GEMMs.
        assert_eq!(report.elementwise_ops, 8);
        // Resident consumers: (qkv, fc, fcproj) x 2 layers + lm-head +
        // softmax.
        assert_eq!(report.resident_edges, 8);
    }

    #[test]
    fn sampling_is_in_vocab() {
        let cfg = ModelConfig::d2();
        let mut model = Gpt2Model::new(cfg, 11);
        let tokens = vec![1i32; 8];
        model
            .forward(&mut MatmulDispatch::Cpu, &tokens, None, 1, 8)
            .unwrap();
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            assert!(model.sample_next(&mut rng, 1.0) < cfg.vocab_size);
        }
        assert!(model.sample_next(&mut rng, 0.0) < cfg.vocab_size);
    }
}
