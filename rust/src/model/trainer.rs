//! The training loop (llm.c's main), CPU or CPU+NPU.
//!
//! Mirrors the paper's evaluation procedure: epochs are timed individually
//! (llm.c's default is 41), per-op wallclock is recorded for Figure 8, the
//! engine's stage breakdown accumulates for Figure 7, and the power meter
//! integrates energy for Figure 9.

use crate::coordinator::engine::GemmOffloadEngine;
use crate::power::meter::PowerMeter;
use crate::power::profiles::PowerProfile;
use crate::util::error::Result;

use super::config::ModelConfig;
use super::data::DataLoader;
use super::model::Gpt2Model;
use super::ops::adamw::AdamW;
use super::ops::matmul::MatmulDispatch;

/// Which implementation the trainer runs — the paper's two bars.
pub enum TrainBackend<'a> {
    /// Vanilla llm.c: everything on the CPU.
    Cpu,
    /// GEMMs offloaded through the engine.
    CpuNpu(&'a mut GemmOffloadEngine),
}

/// One epoch's record.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub wall_s: f64,
    /// Modeled epoch time (CPU cost model + device model), used for
    /// paper-scale comparisons. With a pipelined engine this shrinks by
    /// exactly the host-staging seconds hidden under kernel execution.
    pub modeled_s: f64,
    /// Modeled energy over the epoch (J).
    pub energy_j: f64,
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch: usize,
    pub seq: usize,
    pub epochs: usize,
    /// Steps per epoch (llm.c's "epoch" in the paper is one pass = one
    /// timed unit; we allow multiple steps per epoch for small corpora).
    pub steps_per_epoch: usize,
    pub optimizer: AdamW,
    pub power: PowerProfile,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 4,
            seq: 64,
            epochs: 41,
            steps_per_epoch: 1,
            optimizer: AdamW::default(),
            power: PowerProfile::mains(),
        }
    }
}

/// Run training; returns per-epoch stats.
pub fn train(
    model: &mut Gpt2Model,
    loader: &mut DataLoader,
    backend: &mut TrainBackend,
    cfg: &TrainConfig,
) -> Result<Vec<EpochStats>> {
    // The pipeline timeline should measure device spans in profile time so
    // its hidden/exposed host-staging split reflects this power state
    // (battery stretches kernels, hiding more staging).
    if let TrainBackend::CpuNpu(engine) = backend {
        engine.set_device_time_scale(cfg.power.npu_time_scale);
    }
    let mut out = Vec::with_capacity(cfg.epochs);
    for epoch in 0..cfg.epochs {
        let mut meter = PowerMeter::new(cfg.power.clone());
        let t0 = std::time::Instant::now();
        let mut loss = 0.0f32;
        let mut gnorm = 0.0f32;
        // Offload accounting from the engine's pipeline timeline: device
        // spans (scaled by the power profile's NPU throttle) plus the host
        // staging that was *not* hidden under device work. A serial engine
        // hides nothing; a pipelined engine's epochs shrink by exactly the
        // hidden host-staging seconds — never by double-counted kernels.
        let mut npu_device_s = 0.0f64;
        let mut npu_host_exposed_s = 0.0f64;
        let mut npu_energy_j = 0.0f64;
        for _ in 0..cfg.steps_per_epoch {
            let (tokens, targets) = loader.next_batch();
            let (l, g) = match backend {
                TrainBackend::Cpu => {
                    let mut d = MatmulDispatch::Cpu;
                    let l = model
                        .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                        .unwrap();
                    model.zero_grad();
                    model.backward(&mut d)?;
                    (l, model.update(&cfg.optimizer))
                }
                TrainBackend::CpuNpu(engine) => {
                    let before_device = engine.pipeline.device_busy_s;
                    let before_exposed = engine.pipeline.exposed_host_s();
                    let before_energy = engine.modeled_energy_j;
                    let mut d = MatmulDispatch::Npu(engine);
                    let l = model
                        .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                        .unwrap();
                    model.zero_grad();
                    model.backward(&mut d)?;
                    let g = model.update(&cfg.optimizer);
                    npu_device_s += engine.pipeline.device_busy_s - before_device;
                    npu_host_exposed_s += engine.pipeline.exposed_host_s() - before_exposed;
                    npu_energy_j += engine.modeled_energy_j - before_energy;
                    (l, g)
                }
            };
            loss = l;
            gnorm = g;
        }
        let wall = t0.elapsed().as_secs_f64();
        // Modeled epoch time: CPU ops at the profile's effective rate +
        // modeled NPU seconds for offloaded GEMMs. Device spans are
        // already in profile time (set_device_time_scale above); exposed
        // host staging does not throttle with the NPU.
        let modeled = match backend {
            TrainBackend::Cpu => {
                cfg.steps_per_epoch as f64
                    * cfg.power.modeled_epoch_s(&model.cfg, cfg.batch, cfg.seq, false)
            }
            TrainBackend::CpuNpu(_) => {
                cfg.steps_per_epoch as f64
                    * cfg.power.modeled_epoch_s(&model.cfg, cfg.batch, cfg.seq, true)
                    + npu_device_s
                    + npu_host_exposed_s
            }
        };
        let energy = meter.integrate_epoch(modeled, matches!(backend, TrainBackend::CpuNpu(_)))
            + npu_energy_j;
        out.push(EpochStats {
            epoch,
            loss,
            grad_norm: gnorm,
            wall_s: wall,
            modeled_s: modeled,
            energy_j: energy,
        });
    }
    Ok(out)
}

/// Quick helper: train a named config on a synthetic corpus.
pub fn train_synthetic(
    model_cfg: ModelConfig,
    train_cfg: &TrainConfig,
    backend: &mut TrainBackend,
    seed: u64,
) -> Result<Vec<EpochStats>> {
    let corpus = super::data::synthetic_corpus(
        model_cfg.vocab_size,
        (train_cfg.batch * train_cfg.seq + 1) * train_cfg.steps_per_epoch.max(4) * 4,
        seed,
    );
    let mut loader = DataLoader::new(corpus, train_cfg.batch, train_cfg.seq)?;
    let mut model = Gpt2Model::new(model_cfg, seed);
    train(&mut model, &mut loader, backend, train_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_training_loss_decreases() {
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 6,
            steps_per_epoch: 4,
            ..Default::default()
        };
        let stats = train_synthetic(cfg, &tc, &mut TrainBackend::Cpu, 3).unwrap();
        assert_eq!(stats.len(), 6);
        assert!(
            stats.last().unwrap().loss < stats[0].loss,
            "{} -> {}",
            stats[0].loss,
            stats.last().unwrap().loss
        );
        assert!(stats[0].wall_s > 0.0);
        assert!(stats[0].energy_j > 0.0);
    }

    #[test]
    fn npu_training_tracks_cpu() {
        use crate::coordinator::engine::{EngineConfig, GemmOffloadEngine};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 3,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let cpu = train_synthetic(cfg, &tc, &mut TrainBackend::Cpu, 5).unwrap();
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let npu = train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut eng), 5).unwrap();
        for (c, n) in cpu.iter().zip(&npu) {
            assert!(
                (c.loss - n.loss).abs() < 0.05 * c.loss.max(1.0),
                "epoch {}: {} vs {}",
                c.epoch,
                c.loss,
                n.loss
            );
        }
        // Offloaded epochs are modeled faster than CPU epochs at 124M
        // scale; at d2 scale overheads dominate, so just require sane
        // bookkeeping here (the fig8/fig9 benches assert the real claim).
        assert!(npu[0].modeled_s > 0.0);
        assert!(eng.invocations > 0);
    }

    #[test]
    fn pipelined_training_is_modeled_no_slower_and_numerically_identical() {
        use crate::coordinator::engine::{EngineConfig, ExecMode, GemmOffloadEngine};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 2,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let mut eng_serial = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let serial =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut eng_serial), 5).unwrap();
        let mut eng_pipe = GemmOffloadEngine::new(
            EngineConfig {
                mode: ExecMode::Pipelined,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let pipe =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut eng_pipe), 5).unwrap();
        for (s, p) in serial.iter().zip(&pipe) {
            // Scheduling must never change numerics.
            assert_eq!(s.loss, p.loss, "epoch {}", s.epoch);
            // Overlap can only hide host staging, never add modeled time.
            assert!(
                p.modeled_s <= s.modeled_s + 1e-9,
                "epoch {}: pipelined {} vs serial {}",
                s.epoch,
                p.modeled_s,
                s.modeled_s
            );
        }
        // The backward pairs really did overlap.
        assert!(eng_pipe.pipeline.hidden_s() > 0.0);
        assert_eq!(eng_serial.pipeline.hidden_s(), 0.0);
    }
}
