//! The training loop (llm.c's main), CPU or CPU+NPU.
//!
//! Mirrors the paper's evaluation procedure: epochs are timed individually
//! (llm.c's default is 41), per-op wallclock is recorded for Figure 8, the
//! engine's stage breakdown accumulates for Figure 7, and the power meter
//! integrates energy for Figure 9.

use crate::coordinator::executor::{self, ExecutorMode};
use crate::coordinator::plan::{PlanCache, StepPlan};
use crate::coordinator::session::{OffloadSession, STAGE_RECONFIG};
use crate::power::meter::PowerMeter;
use crate::power::profiles::PowerProfile;
use crate::util::error::Result;

use super::config::ModelConfig;
use super::data::DataLoader;
use super::model::Gpt2Model;
use super::ops::adamw::AdamW;
use super::ops::matmul::MatmulDispatch;

/// Which implementation the trainer runs — the paper's two bars, plus the
/// deferred step-graph variant.
pub enum TrainBackend<'a> {
    /// Vanilla llm.c: everything on the CPU.
    Cpu,
    /// GEMMs offloaded eagerly through an [`OffloadSession`] (a legacy
    /// `GemmOffloadEngine` derefs to one and coerces here too).
    CpuNpu(&'a mut OffloadSession),
    /// Record→schedule→execute: each training step's GEMMs are recorded
    /// into a [`StepPlan`] (numerics run in place, bit-for-bit the eager
    /// results) and the session schedules the whole step at once —
    /// whole-step same-size batching, deep weight-staging prefetch,
    /// per-size auto-sharding. With a [`PlanCache`], the step is
    /// recorded and scheduled *once*: every later step optimistically
    /// replays the cached schedule (numerics re-run with that step's
    /// data) and re-records only when the GEMM stream diverges — a shape
    /// or config change.
    CpuNpuPlanned {
        session: &'a mut OffloadSession,
        /// `Some` enables cross-step plan caching (`--plan-cache on`).
        cache: Option<&'a mut PlanCache>,
        /// How cached-step replays are driven (`--executor
        /// sync|background`). `Background` — the default — hands the
        /// device-stage loop to the executor thread when a cached plan
        /// exists, so staging + device wallclock overlaps the trainer's
        /// CPU ops for real; recording (and every step without a cached
        /// plan) always runs synchronously. Numerics are bit-identical
        /// either way.
        executor: ExecutorMode,
    },
}

/// One epoch's record.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub wall_s: f64,
    /// Modeled epoch time (CPU cost model + device model), used for
    /// paper-scale comparisons. With a pipelined engine this shrinks by
    /// exactly the host-staging seconds hidden under kernel execution.
    pub modeled_s: f64,
    /// Modeled energy over the epoch (J).
    pub energy_j: f64,
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch: usize,
    pub seq: usize,
    pub epochs: usize,
    /// Steps per epoch (llm.c's "epoch" in the paper is one pass = one
    /// timed unit; we allow multiple steps per epoch for small corpora).
    pub steps_per_epoch: usize,
    pub optimizer: AdamW,
    pub power: PowerProfile,
    /// Record the transformer block's non-GEMM ops (layernorm, fused
    /// GELU, softmax) into the step plan with device-resident activation
    /// edges (`--block-offload on`). Changes only the modeled schedule —
    /// numerics always run through the host ops, bit-identical either
    /// way. Applied to the model at the start of [`train`]. Default off:
    /// plans stay GEMM-only, the Figure-7 serial schedule.
    pub block_offload: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 4,
            seq: 64,
            epochs: 41,
            steps_per_epoch: 1,
            optimizer: AdamW::default(),
            power: PowerProfile::mains(),
            block_offload: false,
        }
    }
}

/// Run training; returns per-epoch stats.
pub fn train(
    model: &mut Gpt2Model,
    loader: &mut DataLoader,
    backend: &mut TrainBackend,
    cfg: &TrainConfig,
) -> Result<Vec<EpochStats>> {
    // The pipeline timeline should measure device spans in profile time so
    // its hidden/exposed host-staging split reflects this power state
    // (battery stretches kernels, hiding more staging).
    match backend {
        TrainBackend::CpuNpu(session) | TrainBackend::CpuNpuPlanned { session, .. } => {
            session.set_device_time_scale(cfg.power.npu_time_scale);
        }
        TrainBackend::Cpu => {}
    }
    // Block offload is a recording-time property of the step plan, so it
    // lives on the model (which owns the op stream); the train config is
    // the single switch the CLI and the finetune example flip.
    model.block_offload = cfg.block_offload;
    let mut out = Vec::with_capacity(cfg.epochs);
    for epoch in 0..cfg.epochs {
        let mut meter = PowerMeter::new(cfg.power.clone());
        let t0 = std::time::Instant::now();
        let mut loss = 0.0f32;
        let mut gnorm = 0.0f32;
        // Offload accounting from the session's pipeline timeline: the
        // epoch is charged the growth of the overlapped schedule's
        // makespan (device spans are already in profile time via
        // set_device_time_scale above). On a depth-1 session this equals
        // the serial stage sum; deeper rings shrink it by exactly the
        // hidden host staging, and sharded dispatch by the strip time
        // hidden under other columns — never by double-counted kernels
        // (makespan never drops below any single column's load). Using
        // the makespan delta rather than device-busy + exposed-host keeps
        // the charge correct on multi-column timelines, where hidden time
        // can exceed host staging and exposed_host_s() clamps at zero.
        let mut npu_offload_s = 0.0f64;
        // Per-column accounting marks for the epoch's energy: the NPU is
        // charged active draw for each column's busy growth, the idle
        // floor for the rest of the epoch, and reconfiguration draw for
        // the modeled barriers — not a flat array-active assumption.
        let (col_mark, reconfig_mark) = match backend {
            TrainBackend::CpuNpu(session) | TrainBackend::CpuNpuPlanned { session, .. } => (
                session.pipeline.col_busy_s.clone(),
                modeled_reconfig_s(session),
            ),
            TrainBackend::Cpu => (Vec::new(), 0.0),
        };
        for _ in 0..cfg.steps_per_epoch {
            let (tokens, targets) = loader.next_batch();
            let (l, g) = match backend {
                TrainBackend::Cpu => {
                    let mut d = MatmulDispatch::Cpu;
                    let l = model
                        .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                        .unwrap();
                    model.zero_grad();
                    model.backward(&mut d)?;
                    (l, model.update(&cfg.optimizer))
                }
                TrainBackend::CpuNpu(session) => {
                    let before_makespan = session.pipeline.makespan_s();
                    let mut host_step = session.quarantined();
                    let mut l = 0.0f32;
                    if !host_step {
                        let step = (|| -> Result<f32> {
                            let mut d = MatmulDispatch::Npu(&mut **session);
                            let l = model
                                .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                                .unwrap();
                            model.zero_grad();
                            model.backward(&mut d)?;
                            Ok(l)
                        })();
                        match step {
                            Ok(v) => l = v,
                            // The session quarantined mid-step (retries and
                            // recovery exhausted). The step is re-run below
                            // on the host oracle — zero_grad wipes any
                            // partial gradients, so the step's numerics are
                            // all-host, bit-identical to the Cpu backend.
                            Err(_) if session.quarantined() => host_step = true,
                            Err(e) => return Err(e),
                        }
                    }
                    if host_step {
                        session.faults.fallback_steps += 1;
                        let mut d = MatmulDispatch::HostFallback(&mut **session);
                        l = model
                            .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                            .unwrap();
                        model.zero_grad();
                        model.backward(&mut d)?;
                    }
                    let g = model.update(&cfg.optimizer);
                    npu_offload_s += session.pipeline.makespan_s() - before_makespan;
                    (l, g)
                }
                TrainBackend::CpuNpuPlanned { session, cache, executor } => {
                    let before_makespan = session.pipeline.makespan_s();
                    let exec_mode = *executor;
                    // A quarantined session never reaches the device
                    // again: the whole step runs on the host oracle and
                    // the plan cache is skipped (nothing device-side to
                    // replay or record).
                    let mut host_step = session.quarantined();
                    // Optimistic cache hit: re-run the step's numerics
                    // against the most recently cached plan and charge
                    // the frozen schedule. Any divergence (a shape
                    // change) is recoverable — fall through and record.
                    let mut replayed: Option<f32> = None;
                    if let Some(c) = cache.as_deref_mut().filter(|_| !host_step) {
                        if exec_mode == ExecutorMode::Background && session.in_flight() == 0 {
                            if let Some(entry) = c.latest_for(session.session_id()) {
                                // Background: the executor thread owns the
                                // session for the step and drains the
                                // device-stage loop, so forward/backward
                                // CPU work genuinely overlaps staging +
                                // device wallclock (recording below stays
                                // synchronous either way).
                                let step = executor::run_replay_step(
                                    &mut **session,
                                    entry,
                                    |client| {
                                        let mut d =
                                            MatmulDispatch::BackgroundReplay { client };
                                        let l = model
                                            .forward(
                                                &mut d,
                                                &tokens,
                                                Some(&targets),
                                                cfg.batch,
                                                cfg.seq,
                                            )?
                                            .unwrap();
                                        model.zero_grad();
                                        model.backward(&mut d)?;
                                        // Apply the deferred dW jobs the
                                        // backward pass named by arena
                                        // offset — the gradient arena's
                                        // only live borrow is right here.
                                        let MatmulDispatch::BackgroundReplay { client } = d
                                        else {
                                            unreachable!("dispatch fixed above")
                                        };
                                        client
                                            .drain_and_apply(model.grads.as_mut_slice())?;
                                        Ok(l)
                                    },
                                );
                                match step {
                                    Ok((l, _report)) => {
                                        c.record_hit();
                                        replayed = Some(l);
                                    }
                                    Err(e) if e.is_plan_divergence() => {}
                                    // Quarantined mid-replay: fall through
                                    // to the host-oracle step below.
                                    Err(_) if session.quarantined() => host_step = true,
                                    Err(e) => return Err(e),
                                }
                            }
                        } else if let Some(mut replay) = session.begin_replay(c) {
                            let step = (|| -> Result<f32> {
                                let mut d = MatmulDispatch::Replay {
                                    session: &mut **session,
                                    replay: &mut replay,
                                };
                                let l = model
                                    .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                                    .unwrap();
                                model.zero_grad();
                                model.backward(&mut d)?;
                                Ok(l)
                            })();
                            match step {
                                Ok(l) => match session.finish_replay(replay) {
                                    Ok(_) => {
                                        c.record_hit();
                                        replayed = Some(l);
                                    }
                                    Err(e) if e.is_plan_divergence() => {}
                                    Err(_) if session.quarantined() => host_step = true,
                                    Err(e) => return Err(e),
                                },
                                Err(e) if e.is_plan_divergence() => {}
                                // Quarantined mid-replay: fall through to
                                // the host-oracle step below.
                                Err(_) if session.quarantined() => host_step = true,
                                Err(e) => return Err(e),
                            }
                        }
                    }
                    if !host_step && replayed.is_none() {
                        // Record the whole step (forward/backward are
                        // deterministic, so a diverged half-replayed
                        // step reruns cleanly — zero_grad wipes any
                        // partial gradients), then let the scheduler
                        // see it at once and freeze the schedule for
                        // every later step.
                        let step = (|| -> Result<f32> {
                            let mut plan = StepPlan::new();
                            let l = {
                                let mut d = MatmulDispatch::Plan {
                                    session: &mut **session,
                                    plan: &mut plan,
                                };
                                let l = model
                                    .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                                    .unwrap();
                                model.zero_grad();
                                model.backward(&mut d)?;
                                l
                            };
                            session.execute(&mut plan)?;
                            if let Some(c) = cache.as_deref_mut() {
                                c.insert(session.freeze(plan)?);
                            }
                            Ok(l)
                        })();
                        match step {
                            Ok(l) => replayed = Some(l),
                            // Quarantined while executing the recorded
                            // step: re-run it on the host oracle below.
                            Err(_) if session.quarantined() => host_step = true,
                            Err(e) => return Err(e),
                        }
                    }
                    let l = if host_step {
                        // The whole step runs on the host oracle —
                        // zero_grad wipes any partial gradients from a
                        // failed attempt, so the step's numerics are
                        // all-host, bit-identical to the Cpu backend.
                        session.faults.fallback_steps += 1;
                        let mut d = MatmulDispatch::HostFallback(&mut **session);
                        let l = model
                            .forward(&mut d, &tokens, Some(&targets), cfg.batch, cfg.seq)?
                            .unwrap();
                        model.zero_grad();
                        model.backward(&mut d)?;
                        l
                    } else {
                        replayed.expect("step either replayed, recorded, or fell back to host")
                    };
                    let g = model.update(&cfg.optimizer);
                    npu_offload_s += session.pipeline.makespan_s() - before_makespan;
                    (l, g)
                }
            };
            loss = l;
            gnorm = g;
        }
        let wall = t0.elapsed().as_secs_f64();
        // Modeled epoch time: CPU ops at the profile's effective rate +
        // the offloaded GEMM schedule's makespan growth over this epoch.
        let modeled = match backend {
            TrainBackend::Cpu => {
                cfg.steps_per_epoch as f64
                    * cfg.power.modeled_epoch_s(&model.cfg, cfg.batch, cfg.seq, false)
            }
            TrainBackend::CpuNpu(_) | TrainBackend::CpuNpuPlanned { .. } => {
                cfg.steps_per_epoch as f64
                    * cfg.power.modeled_epoch_s(&model.cfg, cfg.batch, cfg.seq, true)
                    + npu_offload_s
            }
        };
        let energy = match backend {
            TrainBackend::Cpu => meter.integrate_epoch(modeled, false),
            TrainBackend::CpuNpu(session) | TrainBackend::CpuNpuPlanned { session, .. } => {
                let col_busy_s: Vec<f64> = session
                    .pipeline
                    .col_busy_s
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| (b - col_mark.get(i).copied().unwrap_or(0.0)).max(0.0))
                    .collect();
                let reconfig_s = (modeled_reconfig_s(session) - reconfig_mark).max(0.0);
                meter.integrate_epoch_offloaded(
                    modeled,
                    &session.dev.npu.power,
                    &col_busy_s,
                    reconfig_s,
                )
            }
        };
        out.push(EpochStats {
            epoch,
            loss,
            grad_norm: gnorm,
            wall_s: wall,
            modeled_s: modeled,
            energy_j: energy,
        });
    }
    Ok(out)
}

/// The session's accumulated modeled reconfiguration seconds (the
/// Figure-7 reconfig stage) — epoch deltas feed the energy meter's
/// barrier pricing.
fn modeled_reconfig_s(session: &OffloadSession) -> f64 {
    session
        .modeled_stages
        .iter()
        .find(|(n, _)| n == STAGE_RECONFIG)
        .map(|(_, s)| *s)
        .unwrap_or(0.0)
}

/// The on-disk plan-cache key for a training run (`--plan-cache-file`):
/// the session's schedule-configuration fingerprint combined with the
/// model config and step shape. One helper shared by the CLI and the
/// finetune example, so a cache file written by either is adopted by the
/// other — and so the key can never silently drift between them.
pub fn plan_cache_fingerprint(
    session: &OffloadSession,
    cfg: &ModelConfig,
    batch: usize,
    seq: usize,
) -> u64 {
    session.config_fingerprint()
        ^ crate::coordinator::plan::fingerprint_str(&format!("{cfg:?}|B{batch}xT{seq}"))
}

/// Quick helper: train a named config on a synthetic corpus.
pub fn train_synthetic(
    model_cfg: ModelConfig,
    train_cfg: &TrainConfig,
    backend: &mut TrainBackend,
    seed: u64,
) -> Result<Vec<EpochStats>> {
    let corpus = super::data::synthetic_corpus(
        model_cfg.vocab_size,
        (train_cfg.batch * train_cfg.seq + 1) * train_cfg.steps_per_epoch.max(4) * 4,
        seed,
    );
    let mut loader = DataLoader::new(corpus, train_cfg.batch, train_cfg.seq)?;
    let mut model = Gpt2Model::new(model_cfg, seed);
    train(&mut model, &mut loader, backend, train_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_training_loss_decreases() {
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 6,
            steps_per_epoch: 4,
            ..Default::default()
        };
        let stats = train_synthetic(cfg, &tc, &mut TrainBackend::Cpu, 3).unwrap();
        assert_eq!(stats.len(), 6);
        assert!(
            stats.last().unwrap().loss < stats[0].loss,
            "{} -> {}",
            stats[0].loss,
            stats.last().unwrap().loss
        );
        assert!(stats[0].wall_s > 0.0);
        assert!(stats[0].energy_j > 0.0);
    }

    #[test]
    fn npu_training_tracks_cpu() {
        use crate::coordinator::engine::{EngineConfig, GemmOffloadEngine};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 3,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let cpu = train_synthetic(cfg, &tc, &mut TrainBackend::Cpu, 5).unwrap();
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let npu = train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut eng), 5).unwrap();
        for (c, n) in cpu.iter().zip(&npu) {
            assert!(
                (c.loss - n.loss).abs() < 0.05 * c.loss.max(1.0),
                "epoch {}: {} vs {}",
                c.epoch,
                c.loss,
                n.loss
            );
        }
        // Offloaded epochs are modeled faster than CPU epochs at 124M
        // scale; at d2 scale overheads dominate, so just require sane
        // bookkeeping here (the fig8/fig9 benches assert the real claim).
        assert!(npu[0].modeled_s > 0.0);
        assert!(eng.invocations > 0);
    }

    #[test]
    fn deeper_ring_training_is_modeled_no_slower_and_numerically_identical() {
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 2,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let mut sess_serial = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
        let serial =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess_serial), 5).unwrap();
        let mut sess_deep = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let deep =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess_deep), 5).unwrap();
        for (s, p) in serial.iter().zip(&deep) {
            // Scheduling must never change numerics.
            assert_eq!(s.loss, p.loss, "epoch {}", s.epoch);
            // Overlap can only hide host staging, never add modeled time.
            assert!(
                p.modeled_s <= s.modeled_s + 1e-9,
                "epoch {}: depth-2 {} vs serial {}",
                s.epoch,
                p.modeled_s,
                s.modeled_s
            );
        }
        // The backward pairs really did overlap.
        assert!(sess_deep.pipeline.hidden_s() > 0.0);
        assert_eq!(sess_serial.pipeline.hidden_s(), 0.0);
    }

    #[test]
    fn planned_training_is_bit_identical_and_modeled_no_slower_than_eager() {
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 2,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let mut sess_eager = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let eager =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess_eager), 5).unwrap();
        // FIFO isolates the prefetch win: the replay is the eager schedule
        // with weight staging hoisted, so it can only be faster. (The
        // BatchBySize + prefetch acceptance runs in rust/tests/plan.rs.)
        let mut sess_plan = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let planned = train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess_plan,
                cache: None,
                executor: ExecutorMode::Sync,
            },
            5,
        )
        .unwrap();
        for (e, p) in eager.iter().zip(&planned) {
            assert_eq!(e.loss, p.loss, "epoch {}: recording must not change numerics", e.epoch);
            assert!(
                p.modeled_s <= e.modeled_s + 1e-9,
                "epoch {}: planned {} must not be modeled slower than eager {}",
                e.epoch,
                p.modeled_s,
                e.modeled_s
            );
        }
        assert!(sess_plan.invocations > 0);
        assert!(sess_plan.pipeline.hidden_s() > 0.0, "the planned step must overlap");
    }

    #[test]
    fn cached_planned_training_records_once_and_stays_bit_identical() {
        use crate::coordinator::plan::PlanCache;
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 3,
            steps_per_epoch: 2,
            ..Default::default()
        };
        // Eager baseline and an uncached planned run for comparison.
        let mut sess_eager = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let eager =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess_eager), 5).unwrap();
        let mut sess_plain = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let plain = train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess_plain,
                cache: None,
                executor: ExecutorMode::Sync,
            },
            5,
        )
        .unwrap();

        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut cache = PlanCache::new();
        let cached = train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess,
                cache: Some(&mut cache),
                executor: ExecutorMode::Sync,
            },
            5,
        )
        .unwrap();

        // Records exactly once; every later step is a cache hit.
        assert_eq!(cache.misses(), 1, "the step should record exactly once");
        assert_eq!(cache.hits(), 5, "all later steps should replay");
        assert_eq!(cache.len(), 1);
        for ((c, e), p) in cached.iter().zip(&eager).zip(&plain) {
            // Replayed numerics are bit-identical to eager and to the
            // uncached planned run.
            assert_eq!(c.loss, e.loss, "epoch {}: replay must match eager", c.epoch);
            assert_eq!(c.loss, p.loss, "epoch {}", c.epoch);
            // The cached replay charges the same steady-state schedule a
            // fresh record would have.
            assert!(
                (c.modeled_s - p.modeled_s).abs() <= 1e-9 * p.modeled_s.max(1.0),
                "epoch {}: cached {} vs planned {}",
                c.epoch,
                c.modeled_s,
                p.modeled_s
            );
        }
    }

    #[test]
    fn cached_training_rerecords_when_the_session_changes() {
        use crate::coordinator::plan::PlanCache;
        use crate::coordinator::session::{
            OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
        };
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 1,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let mut cache = PlanCache::new();
        let mut sess_a = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess_a,
                cache: Some(&mut cache),
                executor: ExecutorMode::Sync,
            },
            5,
        )
        .unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        // A new session (different shard config): its plans are scoped to
        // it, so the run re-records once rather than replaying session
        // A's entry.
        let mut sess_b = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                shards: ShardPolicy::Fixed(Shards(4)),
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess_b,
                cache: Some(&mut cache),
                executor: ExecutorMode::Sync,
            },
            5,
        )
        .unwrap();
        assert_eq!((cache.hits(), cache.misses()), (2, 2), "one fresh record per session");
        assert_eq!(cache.len(), 2, "both sessions' steps stay cached");
    }

    #[test]
    fn background_executor_training_is_bit_identical_to_sync_and_hits_the_cache() {
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 3,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let run = |mode: ExecutorMode| {
            let mut sess = OffloadSession::new(
                SessionConfig {
                    depth: QueueDepth(2),
                    ..Default::default()
                },
                &[],
            )
            .unwrap();
            let mut cache = PlanCache::new();
            let stats = train_synthetic(
                cfg,
                &tc,
                &mut TrainBackend::CpuNpuPlanned {
                    session: &mut sess,
                    cache: Some(&mut cache),
                    executor: mode,
                },
                5,
            )
            .unwrap();
            (
                stats,
                cache.hits(),
                cache.misses(),
                sess.wall_gemm_s,
                sess.wall_blocked_s,
                sess.pipeline.makespan_s(),
            )
        };
        let (sync, h_s, m_s, gemm_s, blocked_s, mk_s) = run(ExecutorMode::Sync);
        let (bg, h_b, m_b, gemm_b, blocked_b, mk_b) = run(ExecutorMode::Background);
        // Same record-once / replay-thereafter cadence...
        assert_eq!((h_s, m_s), (5, 1));
        assert_eq!((h_b, m_b), (5, 1));
        // ...bit-identical losses step for step...
        for (s, b) in sync.iter().zip(&bg) {
            assert_eq!(
                s.loss, b.loss,
                "epoch {}: the background executor must not change numerics",
                s.epoch
            );
        }
        // ...and an identical modeled timeline (the frozen schedule is
        // charged the same either way).
        assert!((mk_s - mk_b).abs() < 1e-12, "{mk_s} vs {mk_b}");
        // The sync run blocks for every measured GEMM second; the
        // background run's blocked time is whatever waiting remained
        // after overlap (both splits are measured, so just sanity-check
        // them).
        assert!(gemm_s > 0.0 && gemm_b > 0.0);
        assert!((blocked_s - gemm_s).abs() < 1e-12, "sync: blocked == serialized");
        assert!(blocked_b >= 0.0);
    }

    #[test]
    fn block_offload_training_is_bit_identical_and_counts_resident_edges() {
        use crate::coordinator::plan::PlanCache;
        use crate::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
        let cfg = ModelConfig::d2();
        let tc_base = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 3,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let run = |block: bool, mode: ExecutorMode| {
            let mut sess = OffloadSession::new(
                SessionConfig {
                    depth: QueueDepth(2),
                    ..Default::default()
                },
                &[],
            )
            .unwrap();
            let mut cache = PlanCache::new();
            let tc = TrainConfig {
                block_offload: block,
                ..tc_base.clone()
            };
            let stats = train_synthetic(
                cfg,
                &tc,
                &mut TrainBackend::CpuNpuPlanned {
                    session: &mut sess,
                    cache: Some(&mut cache),
                    executor: mode,
                },
                5,
            )
            .unwrap();
            (
                stats,
                cache.hits(),
                cache.misses(),
                sess.resident_edges,
                sess.elementwise_ops,
                sess.pipeline.serial_s(),
            )
        };
        let (off, h_off, m_off, edges_off, elem_off, serial_off) = run(false, ExecutorMode::Sync);
        let (on, h_on, m_on, edges_on, elem_on, serial_on) = run(true, ExecutorMode::Sync);
        let (bg, h_bg, m_bg, edges_bg, elem_bg, _) = run(true, ExecutorMode::Background);
        // Same record-once / replay-thereafter cadence with the block
        // chain in the plan...
        assert_eq!((h_off, m_off), (5, 1));
        assert_eq!((h_on, m_on), (5, 1));
        assert_eq!((h_bg, m_bg), (5, 1));
        // ...numerics bit-identical: block offload changes only the
        // modeled schedule, on every rung.
        for ((o, n), b) in off.iter().zip(&on).zip(&bg) {
            assert_eq!(o.loss, n.loss, "epoch {}: block offload must not change numerics", o.epoch);
            assert_eq!(o.loss, b.loss, "epoch {}: background block offload", o.epoch);
        }
        // GEMM-only plans never count resident edges or elementwise ops;
        // the block chain counts both on every executed/replayed step.
        assert_eq!((edges_off, elem_off), (0, 0));
        assert!(edges_on > 0 && elem_on > 0, "{edges_on} edges, {elem_on} elementwise");
        assert_eq!((edges_bg, elem_bg), (edges_on, elem_on));
        // Kept-resident activations eliminate host round-trips from the
        // modeled schedule: the block-offloaded run's serial stage sum
        // beats the GEMM-only run's (the strict *makespan* win is pinned
        // on the serial schedule in rust/tests/block_offload.rs).
        assert!(serial_on < serial_off, "block {serial_on} vs gemm-only {serial_off}");
    }

    #[test]
    fn sharded_and_scheduled_training_matches_serial_losses() {
        use crate::coordinator::scheduler::SchedulePolicy;
        use crate::coordinator::session::{
            OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
        };
        let cfg = ModelConfig::d2();
        let tc = TrainConfig {
            batch: 2,
            seq: 16,
            epochs: 2,
            steps_per_epoch: 2,
            ..Default::default()
        };
        let mut sess_serial = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
        let serial =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess_serial), 9).unwrap();
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                shards: ShardPolicy::Fixed(Shards(4)),
                schedule: SchedulePolicy::BatchBySize,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let sharded =
            train_synthetic(cfg, &tc, &mut TrainBackend::CpuNpu(&mut sess), 9).unwrap();
        for (s, p) in serial.iter().zip(&sharded) {
            assert_eq!(
                s.loss, p.loss,
                "epoch {}: sharding/scheduling must not change the loss",
                s.epoch
            );
        }
        assert_eq!(sess.pipeline.columns(), 4);
        assert!(sess.invocations > 0);
    }
}
