//! Training data: a synthetic corpus generator + the llm.c-style batch
//! loader, plus binary token-file I/O and checkpointing.
//!
//! The paper fine-tunes on llm.c's default corpus; offline we synthesize a
//! corpus with enough structure to be learnable (a token-level Markov
//! chain over a small alphabet embedded in the model's vocab), which
//! exercises identical code paths and produces a falling loss curve.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::config::ModelConfig;
use super::params::ParamTensors;

/// Generate a synthetic corpus of `len` tokens in [0, vocab): a Markov
/// chain whose transition structure the model can learn (each state
/// prefers a small set of successors).
pub fn synthetic_corpus(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
    assert!(vocab >= 4);
    let mut rng = Rng::new(seed);
    let branch = 4usize;
    // successors[s] = the handful of likely next tokens for state s.
    let successors: Vec<Vec<i32>> = (0..vocab)
        .map(|_| (0..branch).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let mut out = Vec::with_capacity(len);
    let mut state = rng.below(vocab);
    for _ in 0..len {
        // 90% follow the chain, 10% jump anywhere (noise floor).
        let next = if rng.next_f32() < 0.9 {
            successors[state][rng.below(branch)]
        } else {
            rng.below(vocab) as i32
        };
        out.push(next);
        state = next as usize;
    }
    out
}

/// Sequential batch loader over a token stream (llm.c DataLoader: windows
/// of B*T+1 tokens, targets shifted by one).
#[derive(Debug, Clone)]
pub struct DataLoader {
    tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    pos: usize,
}

impl DataLoader {
    pub fn new(tokens: Vec<i32>, batch: usize, seq: usize) -> Result<DataLoader> {
        if tokens.len() < batch * seq + 1 {
            return Err(Error::config(format!(
                "corpus of {} tokens too small for B={batch} T={seq}",
                tokens.len()
            )));
        }
        Ok(DataLoader {
            tokens,
            batch,
            seq,
            pos: 0,
        })
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        (self.tokens.len() - 1) / (self.batch * self.seq)
    }

    /// Next (inputs, targets) pair, wrapping at the end (llm.c resets).
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let need = self.batch * self.seq + 1;
        if self.pos + need > self.tokens.len() {
            self.pos = 0;
        }
        let window = &self.tokens[self.pos..self.pos + need];
        let inputs = window[..need - 1].to_vec();
        let targets = window[1..].to_vec();
        self.pos += self.batch * self.seq;
        (inputs, targets)
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }
}

/// Write a token file (u32 little-endian, llm.c-style: magic + version +
/// count header).
pub fn save_tokens(path: impl AsRef<Path>, tokens: &[i32]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&0x544F4B31u32.to_le_bytes())?; // "TOK1"
    f.write_all(&(tokens.len() as u64).to_le_bytes())?;
    for t in tokens {
        f.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

/// Read a token file written by [`save_tokens`].
pub fn load_tokens(path: impl AsRef<Path>) -> Result<Vec<i32>> {
    let mut f = std::fs::File::open(path)?;
    let mut hdr = [0u8; 12];
    f.read_exact(&mut hdr)?;
    if u32::from_le_bytes(hdr[0..4].try_into().unwrap()) != 0x544F4B31 {
        return Err(Error::config("bad token file magic"));
    }
    let n = u64::from_le_bytes(hdr[4..12].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Checkpoint format: magic, config dims, then the flat f32 parameter
/// arena (llm.c's gpt2_write layout in spirit).
pub fn save_checkpoint(
    path: impl AsRef<Path>,
    cfg: &ModelConfig,
    params: &ParamTensors,
) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&0x47505432u32.to_le_bytes())?; // "GPT2"
    for dim in [
        cfg.max_seq_len,
        cfg.vocab_size,
        cfg.padded_vocab_size,
        cfg.num_layers,
        cfg.num_heads,
        cfg.channels,
    ] {
        f.write_all(&(dim as u32).to_le_bytes())?;
    }
    // SAFETY: f32 slice to bytes view for bulk write.
    let data = params.as_slice();
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    f.write_all(bytes)?;
    Ok(())
}

/// Load a checkpoint; validates dims against `cfg`.
pub fn load_checkpoint(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<ParamTensors> {
    let mut f = std::fs::File::open(path)?;
    let mut hdr = [0u8; 4 + 6 * 4];
    f.read_exact(&mut hdr)?;
    if u32::from_le_bytes(hdr[0..4].try_into().unwrap()) != 0x47505432 {
        return Err(Error::config("bad checkpoint magic"));
    }
    let dims: Vec<u32> = hdr[4..]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let expect = [
        cfg.max_seq_len,
        cfg.vocab_size,
        cfg.padded_vocab_size,
        cfg.num_layers,
        cfg.num_heads,
        cfg.channels,
    ];
    for (i, (&got, &want)) in dims.iter().zip(expect.iter()).enumerate() {
        if got as usize != want {
            return Err(Error::config(format!(
                "checkpoint dim {i} is {got}, config wants {want}"
            )));
        }
    }
    let mut params = ParamTensors::zeros(cfg);
    let data = params.as_mut_slice();
    let mut buf = vec![0u8; data.len() * 4];
    f.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_learnable_structure() {
        let corpus = synthetic_corpus(64, 10_000, 7);
        assert_eq!(corpus.len(), 10_000);
        assert!(corpus.iter().all(|&t| (0..64).contains(&t)));
        // A Markov corpus has repeating bigrams: distinct bigram count must
        // be far below the 10k-sample worst case.
        let mut bigrams = std::collections::BTreeSet::new();
        for w in corpus.windows(2) {
            bigrams.insert((w[0], w[1]));
        }
        assert!(bigrams.len() < 2500, "{} distinct bigrams", bigrams.len());
    }

    #[test]
    fn loader_shifts_targets() {
        let tokens: Vec<i32> = (0..100).collect();
        let mut dl = DataLoader::new(tokens, 2, 4).unwrap();
        let (inp, tgt) = dl.next_batch();
        assert_eq!(inp, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(tgt, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let (inp2, _) = dl.next_batch();
        assert_eq!(inp2[0], 8);
    }

    #[test]
    fn loader_wraps() {
        let tokens: Vec<i32> = (0..17).collect();
        let mut dl = DataLoader::new(tokens, 2, 4).unwrap();
        dl.next_batch();
        dl.next_batch(); // wraps
        let (inp, _) = dl.next_batch();
        assert_eq!(inp[0], 0);
    }

    #[test]
    fn token_file_roundtrip() {
        let dir = std::env::temp_dir().join("xdna_repro_test_tokens.bin");
        let tokens = vec![5i32, -1, 300000, 0];
        save_tokens(&dir, &tokens).unwrap();
        assert_eq!(load_tokens(&dir).unwrap(), tokens);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = ModelConfig::d2();
        let mut rng = crate::util::rng::Rng::new(9);
        let params = ParamTensors::random_init(&cfg, &mut rng);
        let path = std::env::temp_dir().join("xdna_repro_test_ckpt.bin");
        save_checkpoint(&path, &cfg, &params).unwrap();
        let loaded = load_checkpoint(&path, &cfg).unwrap();
        assert!(loaded.allclose(&params, 0.0, 0.0));
        // Wrong config must be rejected.
        assert!(load_checkpoint(&path, &ModelConfig::d4()).is_err());
        let _ = std::fs::remove_file(path);
    }
}
