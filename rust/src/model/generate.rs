//! The serving engine: KV-cached decode with continuous batching on the
//! offload stack.
//!
//! Training drove five PRs of scheduling work (plans, caching, background
//! execution); this module points the same machinery at generation. Each
//! decode step runs the per-token transformer column — 9 GEMMs on d2 —
//! with M = R rows, one per in-flight request (*continuous batching*:
//! requests join and leave the batch between steps, FIFO). The step is
//! recorded once as a [`StepPlan`] and optimistically replayed through a
//! [`PlanCache`] thereafter: decode shapes depend only on the batch
//! occupancy R, so after the first token every step is a cache hit, and
//! an occupancy change is a recoverable divergence that re-records.
//!
//! Numerics are the point of the test suite around this module: the GEMM
//! path computes every output row independently of M, attention reads
//! per-request [`KvCache`] rows copied verbatim from those GEMMs, and
//! sampling shares [`sample_logits`] with the training path — so a
//! KV-cached, batched, plan-replayed decode is **bit-identical** to
//! recomputing the full window per token, request by request.

use crate::coordinator::faults::FaultCounters;
use crate::coordinator::plan::{PlanCache, StepPlan};
use crate::coordinator::session::OffloadSession;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use super::config::ModelConfig;
use super::kv_cache::{KvCache, KvCacheMode};
use super::model::{sample_logits, Gpt2Model};
use super::ops::matmul::{self, MatmulDispatch};
use super::ops::{attention, gelu, layernorm, residual};
use super::params::ParamTensors;

/// One generation request: a non-empty prompt and a token budget.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Per-request sampling seed, so a request's token stream does not
    /// depend on which other requests share its batch.
    pub seed: u64,
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize, seed: u64) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens,
            seed,
        }
    }
}

/// One request's completed generation.
#[derive(Debug, Clone, Default)]
pub struct Generation {
    /// Index into the request slice handed to [`serve`].
    pub id: usize,
    pub tokens: Vec<i32>,
    /// Modeled per-token decode latency (makespan delta of the step that
    /// produced each token).
    pub latencies_s: Vec<f64>,
    /// The padded-vocab logits row this request's final token was sampled
    /// from — the bit-identity probe the test suite compares across
    /// serve configurations.
    pub final_logits: Vec<f32>,
    /// The request hit its decode deadline (`--request-timeout-ms`) and
    /// was retired with this partial token stream.
    pub expired: bool,
}

/// How the serving loop picks the next pending request when a batch slot
/// frees up. Admission only reorders *when* a request starts; each
/// request's token stream is independent of its batchmates (per-request
/// seed and KV-cache), so the policy never changes any request's tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Arrival order — the default, and the behavior every earlier rung
    /// shipped with (bit-identical reports aside from the wait column).
    #[default]
    Fifo,
    /// Shortest-job-first: admit the pending request with the smallest
    /// total footprint (prompt length + token budget), ties by arrival
    /// order. A latency proxy: short requests stop waiting behind long
    /// ones, at the usual SJF fairness cost to the long tail.
    Latency,
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionPolicy::Fifo => write!(f, "fifo"),
            AdmissionPolicy::Latency => write!(f, "latency"),
        }
    }
}

impl std::str::FromStr for AdmissionPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<AdmissionPolicy> {
        match s {
            "fifo" => Ok(AdmissionPolicy::Fifo),
            "latency" => Ok(AdmissionPolicy::Latency),
            other => Err(Error::config(format!(
                "unknown admission policy '{other}' (expected 'fifo' or 'latency')"
            ))),
        }
    }
}

/// Serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Continuous-batching window: max requests decoded per step.
    pub max_batch: usize,
    pub temperature: f32,
    /// `Off` selects the per-token full-window recompute baseline.
    pub kv_cache: KvCacheMode,
    /// Which pending request a free batch slot admits.
    pub admission: AdmissionPolicy,
    /// Per-request decode deadline on the modeled clock
    /// (`--request-timeout-ms`): a request whose generation runs past
    /// its admission time plus this budget is retired with its partial
    /// stream and marked [`Generation::expired`]. `None` (the default)
    /// never expires anything.
    pub request_timeout_s: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 4,
            temperature: 0.8,
            kv_cache: KvCacheMode::On,
            admission: AdmissionPolicy::Fifo,
            request_timeout_s: None,
        }
    }
}

/// What [`serve`] hands back: per-request generations plus the modeled
/// serving telemetry `bench serve` prices.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub generations: Vec<Generation>,
    /// Total tokens generated across all requests.
    pub tokens: usize,
    /// Decode steps executed (a batched step counts once).
    pub steps: usize,
    /// Modeled seconds on the offload session (prefill + decode).
    pub modeled_s: f64,
    /// Portion of `modeled_s` spent in prefill forwards.
    pub prefill_s: f64,
    /// Per-token latencies across all requests, in generation order.
    pub latencies_s: Vec<f64>,
    /// Per-request admission wait, indexed by request id: the modeled
    /// seconds that had elapsed when the request won a batch slot (all
    /// requests arrive at t = 0).
    pub admission_waits_s: Vec<f64>,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Snapshot of the session's cumulative fault-tolerance counters at
    /// the end of the run (all-default on a fault-free session). The
    /// `expired_requests` field counts deadline retirements, which this
    /// serving loop records on the session as they happen.
    pub faults: FaultCounters,
}

impl ServeReport {
    /// Requests retired at their decode deadline with a partial stream.
    pub fn expired_requests(&self) -> usize {
        self.generations.iter().filter(|g| g.expired).count()
    }
}

impl ServeReport {
    /// Modeled decode throughput across the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.modeled_s > 0.0 {
            self.tokens as f64 / self.modeled_s
        } else {
            0.0
        }
    }

    /// Mean batch occupancy: tokens served per decode step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.steps > 0 {
            self.tokens as f64 / self.steps as f64
        } else {
            0.0
        }
    }

    /// Per-token latency percentile (p in 0..=100, nearest-rank on the
    /// sorted latency vector).
    pub fn latency_percentile_s(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let mut sorted = self.latencies_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }
}

/// Scratch arenas for one batched decode step (R rows ≤ max_batch).
struct DecodeActs {
    x: Vec<f32>,
    ln1: Vec<f32>,
    qkv: Vec<f32>,
    atty: Vec<f32>,
    attproj: Vec<f32>,
    res2: Vec<f32>,
    ln2: Vec<f32>,
    fch: Vec<f32>,
    fch_gelu: Vec<f32>,
    fcproj: Vec<f32>,
    lnf: Vec<f32>,
    logits: Vec<f32>,
    mean: Vec<f32>,
    rstd: Vec<f32>,
    /// Attention scratch, one causal row (≤ max_seq_len), reused per
    /// (request, head).
    att: Vec<f32>,
}

impl DecodeActs {
    fn new(cfg: &ModelConfig, max_batch: usize) -> DecodeActs {
        let (c, vp) = (cfg.channels, cfg.padded_vocab_size);
        let r = max_batch;
        DecodeActs {
            x: vec![0.0; r * c],
            ln1: vec![0.0; r * c],
            qkv: vec![0.0; r * 3 * c],
            atty: vec![0.0; r * c],
            attproj: vec![0.0; r * c],
            res2: vec![0.0; r * c],
            ln2: vec![0.0; r * c],
            fch: vec![0.0; r * 4 * c],
            fch_gelu: vec![0.0; r * 4 * c],
            fcproj: vec![0.0; r * c],
            lnf: vec![0.0; r * c],
            logits: vec![0.0; r * vp],
            mean: vec![0.0; r],
            rstd: vec![0.0; r],
            att: vec![0.0; cfg.max_seq_len],
        }
    }
}

/// One in-flight request's decode state.
struct ActiveGen {
    /// Index into the request slice (and `ServeReport::generations`).
    idx: usize,
    /// The token fed to the next decode step.
    token: i32,
    /// Its position in the context window.
    pos: usize,
    remaining: usize,
    rng: Rng,
    kv: KvCache,
}

/// Serve a set of generation requests on one offload session.
///
/// With `cfg.kv_cache` on, requests are decoded through the KV-cached
/// batched engine; pass `Some(cache)` to record each occupancy's decode
/// step once and replay it thereafter. With it off, each request is
/// recomputed token by token over its full window (the eager baseline);
/// `max_batch` and the plan cache are unused there.
pub fn serve(
    model: &mut Gpt2Model,
    requests: &[GenRequest],
    session: &mut OffloadSession,
    mut cache: Option<&mut PlanCache>,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mcfg = model.cfg;
    if requests.is_empty() {
        return Err(Error::config("serve needs at least one request"));
    }
    for (i, r) in requests.iter().enumerate() {
        if r.prompt.is_empty() {
            return Err(Error::config(format!("request {i}: empty prompt")));
        }
        if r.prompt.len() + r.max_new_tokens > mcfg.max_seq_len {
            return Err(Error::config(format!(
                "request {i}: prompt of {} plus {} new tokens exceeds the {}-token context",
                r.prompt.len(),
                r.max_new_tokens,
                mcfg.max_seq_len
            )));
        }
    }
    let mut report = ServeReport {
        generations: (0..requests.len())
            .map(|id| Generation {
                id,
                ..Generation::default()
            })
            .collect(),
        admission_waits_s: vec![0.0; requests.len()],
        ..ServeReport::default()
    };
    let (hits0, misses0) = match cache.as_deref() {
        Some(c) => (c.hits(), c.misses()),
        None => (0, 0),
    };

    if cfg.kv_cache.enabled() {
        serve_kv(model, requests, session, &mut cache, cfg, &mut report)?;
    } else {
        serve_recompute(model, requests, session, cfg, &mut report)?;
    }

    if let Some(c) = cache.as_deref() {
        report.plan_cache_hits = c.hits() - hits0;
        report.plan_cache_misses = c.misses() - misses0;
    }
    report.faults = session.faults.clone();
    Ok(report)
}

/// The KV-cached continuously-batched decode loop.
fn serve_kv(
    model: &mut Gpt2Model,
    requests: &[GenRequest],
    session: &mut OffloadSession,
    cache: &mut Option<&mut PlanCache>,
    cfg: &ServeConfig,
    report: &mut ServeReport,
) -> Result<()> {
    let mcfg = model.cfg;
    let max_batch = cfg.max_batch.max(1);
    let mut scratch = DecodeActs::new(&mcfg, max_batch);
    // Pending request ids, in arrival order. Fifo pops the front —
    // exactly the pre-admission-policy behavior; Latency pops the
    // smallest-footprint request.
    let mut pending: Vec<usize> = (0..requests.len()).collect();
    let mut active: Vec<ActiveGen> = Vec::new();

    loop {
        // Admit until the batching window is full.
        while active.len() < max_batch && !pending.is_empty() {
            let pick = match cfg.admission {
                AdmissionPolicy::Fifo => 0,
                AdmissionPolicy::Latency => pending
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &idx)| {
                        (requests[idx].prompt.len() + requests[idx].max_new_tokens, idx)
                    })
                    .map(|(i, _)| i)
                    .unwrap(),
            };
            let idx = pending.remove(pick);
            report.admission_waits_s[idx] = report.modeled_s;
            if requests[idx].max_new_tokens == 0 {
                continue;
            }
            active.push(admit(model, session, &requests[idx], idx, report)?);
        }
        if active.is_empty() {
            break;
        }

        // One batched decode step: optimistic replay, else record.
        let before = session.pipeline.makespan_s();
        run_decode_step(
            &mcfg,
            &model.params,
            session,
            cache,
            &mut active,
            &mut scratch,
        )?;
        let dt = session.pipeline.makespan_s() - before;
        report.steps += 1;
        report.modeled_s += dt;

        // Sample every active request's next token; retire the finished
        // and the expired. A deadline retirement shrinks the batch, and
        // the occupancy change is the usual recoverable divergence — the
        // next step just re-records.
        let vp = mcfg.padded_vocab_size;
        for (i, a) in active.iter_mut().enumerate() {
            let logits = &scratch.logits[i * vp..(i + 1) * vp];
            let next = sample_logits(logits, mcfg.vocab_size, &mut a.rng, cfg.temperature) as i32;
            let g = &mut report.generations[a.idx];
            g.tokens.push(next);
            g.latencies_s.push(dt);
            report.latencies_s.push(dt);
            report.tokens += 1;
            a.remaining -= 1;
            let expired = matches!(
                cfg.request_timeout_s,
                Some(t) if report.modeled_s - report.admission_waits_s[a.idx] > t
            );
            if a.remaining == 0 {
                g.final_logits = logits.to_vec();
            } else if expired {
                g.final_logits = logits.to_vec();
                g.expired = true;
                session.faults.expired_requests += 1;
                a.remaining = 0;
            } else {
                a.token = next;
                a.pos += 1;
            }
        }
        active.retain(|a| a.remaining > 0);
    }
    Ok(())
}

/// Prefill one request: run the prompt minus its last token through the
/// full forward (eager dispatch) and seed the request's KV-cache from
/// the activation arena. The last prompt token is fed to the first
/// decode step instead, so a T-token generation is exactly T decode
/// steps — one record plus T−1 replays when the plan cache is warm.
fn admit(
    model: &mut Gpt2Model,
    session: &mut OffloadSession,
    req: &GenRequest,
    idx: usize,
    report: &mut ServeReport,
) -> Result<ActiveGen> {
    let p_len = req.prompt.len();
    let mut kv = KvCache::new(&model.cfg);
    if p_len > 1 {
        let before = session.pipeline.makespan_s();
        let prefill = (|| -> Result<()> {
            let mut d = MatmulDispatch::Npu(&mut *session);
            model.forward(&mut d, &req.prompt[..p_len - 1], None, 1, p_len - 1)?;
            Ok(())
        })();
        match prefill {
            Ok(()) => {}
            // Quarantined mid-prefill: re-run the whole prompt on the
            // host oracle (forward is deterministic and overwrites the
            // activation arena in place).
            Err(_) if session.quarantined() => {
                session.faults.fallback_steps += 1;
                let mut d = MatmulDispatch::HostFallback(&mut *session);
                model.forward(&mut d, &req.prompt[..p_len - 1], None, 1, p_len - 1)?;
            }
            Err(e) => return Err(e),
        }
        kv.load_prefill(model.acts.as_ref().unwrap(), p_len - 1);
        let dt = session.pipeline.makespan_s() - before;
        report.modeled_s += dt;
        report.prefill_s += dt;
    }
    Ok(ActiveGen {
        idx,
        token: req.prompt[p_len - 1],
        pos: p_len - 1,
        remaining: req.max_new_tokens,
        rng: Rng::new(req.seed),
        kv,
    })
}

/// Run one decode step through the plan/cache machinery: optimistically
/// replay the most recent cached plan (numerics re-run against this
/// step's data, the frozen schedule is charged), fall back to recording
/// on any divergence — exactly the trainer's cached-step discipline.
fn run_decode_step(
    mcfg: &ModelConfig,
    params: &ParamTensors,
    session: &mut OffloadSession,
    cache: &mut Option<&mut PlanCache>,
    active: &mut [ActiveGen],
    scratch: &mut DecodeActs,
) -> Result<()> {
    // A quarantined session never reaches the device again: decode
    // degrades to the host oracle and skips the plan cache entirely.
    if session.quarantined() {
        return host_decode_step(mcfg, params, session, active, scratch);
    }
    let mut replayed = false;
    if let Some(c) = cache.as_deref_mut() {
        if let Some(mut replay) = session.begin_replay(c) {
            let step = (|| -> Result<()> {
                let mut d = MatmulDispatch::Replay {
                    session: &mut *session,
                    replay: &mut replay,
                };
                decode_step(mcfg, params, &mut d, active, scratch)
            })();
            match step {
                Ok(()) => match session.finish_replay(replay) {
                    Ok(_) => {
                        c.record_hit();
                        replayed = true;
                    }
                    Err(e) if e.is_plan_divergence() => {}
                    Err(_) if session.quarantined() => {
                        return host_decode_step(mcfg, params, session, active, scratch);
                    }
                    Err(e) => return Err(e),
                },
                Err(e) if e.is_plan_divergence() => {}
                // Quarantined mid-replay: the step re-runs on the host
                // oracle (decode is deterministic and KV writes are
                // idempotent, so the half-replayed step reruns cleanly).
                Err(_) if session.quarantined() => {
                    return host_decode_step(mcfg, params, session, active, scratch);
                }
                Err(e) => return Err(e),
            }
        }
    }
    if !replayed {
        // Record the whole step (decode is deterministic and KV writes
        // are idempotent, so a diverged half-replayed step reruns
        // cleanly), schedule it at once, and cache the frozen plan.
        let step = (|| -> Result<()> {
            let mut plan = StepPlan::new();
            {
                let mut d = MatmulDispatch::Plan {
                    session: &mut *session,
                    plan: &mut plan,
                };
                decode_step(mcfg, params, &mut d, active, scratch)?;
            }
            session.execute(&mut plan)?;
            if let Some(c) = cache.as_deref_mut() {
                c.insert(session.freeze(plan)?);
            }
            Ok(())
        })();
        match step {
            Ok(()) => {}
            Err(_) if session.quarantined() => {
                return host_decode_step(mcfg, params, session, active, scratch);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Decode one step entirely on the host oracle — the quarantined
/// session's degraded mode. Numerics are the host ops', bit-identical
/// to a `MatmulDispatch::Cpu` serve of the same requests.
fn host_decode_step(
    mcfg: &ModelConfig,
    params: &ParamTensors,
    session: &mut OffloadSession,
    active: &mut [ActiveGen],
    scratch: &mut DecodeActs,
) -> Result<()> {
    session.faults.fallback_steps += 1;
    let mut d = MatmulDispatch::HostFallback(&mut *session);
    decode_step(mcfg, params, &mut d, active, scratch)
}

/// The per-token transformer column over R = `active.len()` rows — the
/// same op sequence as `Gpt2Model::forward` with attention swapped for
/// the KV-cached [`attention::forward_step`]. 9 GEMMs on d2, all shaped
/// by R only, so the recorded plan is stable across tokens.
fn decode_step(
    cfg: &ModelConfig,
    p: &ParamTensors,
    dispatch: &mut MatmulDispatch,
    active: &mut [ActiveGen],
    s: &mut DecodeActs,
) -> Result<()> {
    let c = cfg.channels;
    let nh = cfg.num_heads;
    let vp = cfg.padded_vocab_size;
    let r = active.len();
    let wte = p.tensor("wte");
    let wpe = p.tensor("wpe");

    // Encoder, one row per request (encoder::forward's per-row op).
    for (i, a) in active.iter().enumerate() {
        let out_row = &mut s.x[i * c..(i + 1) * c];
        let wte_row = &wte[a.token as usize * c..(a.token as usize + 1) * c];
        let wpe_row = &wpe[a.pos * c..(a.pos + 1) * c];
        for j in 0..c {
            out_row[j] = wte_row[j] + wpe_row[j];
        }
    }

    for l in 0..cfg.num_layers {
        layernorm::forward(
            &mut s.ln1[..r * c],
            &mut s.mean[..r],
            &mut s.rstd[..r],
            &s.x[..r * c],
            p.layer("ln1w", l),
            p.layer("ln1b", l),
            r,
            c,
        );
        matmul::forward(
            dispatch,
            &mut s.qkv[..r * 3 * c],
            &s.ln1[..r * c],
            p.layer("qkvw", l),
            Some(p.layer("qkvb", l)),
            r,
            c,
            3 * c,
        )?;
        for (i, a) in active.iter_mut().enumerate() {
            let row = &s.qkv[i * 3 * c..(i + 1) * 3 * c];
            a.kv.write(l, a.pos, &row[c..2 * c], &row[2 * c..3 * c]);
            attention::forward_step(
                &mut s.atty[i * c..(i + 1) * c],
                &mut s.att,
                row,
                a.kv.k_rows(l, a.pos + 1),
                a.kv.v_rows(l, a.pos + 1),
                a.pos,
                c,
                nh,
            );
        }
        matmul::forward(
            dispatch,
            &mut s.attproj[..r * c],
            &s.atty[..r * c],
            p.layer("attprojw", l),
            Some(p.layer("attprojb", l)),
            r,
            c,
            c,
        )?;
        residual::forward(&mut s.res2[..r * c], &s.x[..r * c], &s.attproj[..r * c]);
        layernorm::forward(
            &mut s.ln2[..r * c],
            &mut s.mean[..r],
            &mut s.rstd[..r],
            &s.res2[..r * c],
            p.layer("ln2w", l),
            p.layer("ln2b", l),
            r,
            c,
        );
        matmul::forward(
            dispatch,
            &mut s.fch[..r * 4 * c],
            &s.ln2[..r * c],
            p.layer("fcw", l),
            Some(p.layer("fcb", l)),
            r,
            c,
            4 * c,
        )?;
        gelu::forward(&mut s.fch_gelu[..r * 4 * c], &s.fch[..r * 4 * c]);
        matmul::forward(
            dispatch,
            &mut s.fcproj[..r * c],
            &s.fch_gelu[..r * 4 * c],
            p.layer("fcprojw", l),
            Some(p.layer("fcprojb", l)),
            r,
            4 * c,
            c,
        )?;
        residual::forward(&mut s.x[..r * c], &s.res2[..r * c], &s.fcproj[..r * c]);
    }

    layernorm::forward(
        &mut s.lnf[..r * c],
        &mut s.mean[..r],
        &mut s.rstd[..r],
        &s.x[..r * c],
        p.tensor("lnfw"),
        p.tensor("lnfb"),
        r,
        c,
    );
    // LM head: logits = lnf · wteᵀ (weight sharing, no bias).
    matmul::forward(
        dispatch,
        &mut s.logits[..r * vp],
        &s.lnf[..r * c],
        wte,
        None,
        r,
        c,
        vp,
    )?;
    Ok(())
}

/// The eager per-token recompute baseline (`--kv-cache off`): each
/// request alone, re-running the full growing window for every token.
fn serve_recompute(
    model: &mut Gpt2Model,
    requests: &[GenRequest],
    session: &mut OffloadSession,
    cfg: &ServeConfig,
    report: &mut ServeReport,
) -> Result<()> {
    let vp = model.cfg.padded_vocab_size;
    let mut order: Vec<usize> = (0..requests.len()).collect();
    if cfg.admission == AdmissionPolicy::Latency {
        order.sort_by_key(|&i| (requests[i].prompt.len() + requests[i].max_new_tokens, i));
    }
    for idx in order {
        let req = &requests[idx];
        report.admission_waits_s[idx] = report.modeled_s;
        if req.max_new_tokens == 0 {
            continue;
        }
        let mut rng = Rng::new(req.seed);
        let mut ctx = req.prompt.clone();
        for step in 0..req.max_new_tokens {
            let t = ctx.len();
            let before = session.pipeline.makespan_s();
            let fwd = (|| -> Result<()> {
                let mut d = MatmulDispatch::Npu(&mut *session);
                model.forward(&mut d, &ctx, None, 1, t)?;
                Ok(())
            })();
            match fwd {
                Ok(()) => {}
                // Quarantined mid-window: re-run the window on the host
                // oracle and keep generating.
                Err(_) if session.quarantined() => {
                    session.faults.fallback_steps += 1;
                    let mut d = MatmulDispatch::HostFallback(&mut *session);
                    model.forward(&mut d, &ctx, None, 1, t)?;
                }
                Err(e) => return Err(e),
            }
            let dt = session.pipeline.makespan_s() - before;
            let acts = model.acts.as_ref().unwrap();
            let logits = &acts.logits[(t - 1) * vp..t * vp];
            let next = sample_logits(logits, model.cfg.vocab_size, &mut rng, cfg.temperature);
            let g = &mut report.generations[idx];
            g.tokens.push(next as i32);
            g.latencies_s.push(dt);
            report.latencies_s.push(dt);
            report.tokens += 1;
            report.steps += 1;
            report.modeled_s += dt;
            let expired = matches!(
                cfg.request_timeout_s,
                Some(limit) if report.modeled_s - report.admission_waits_s[idx] > limit
            );
            if step + 1 == req.max_new_tokens {
                g.final_logits = logits.to_vec();
            } else if expired {
                g.final_logits = logits.to_vec();
                g.expired = true;
                session.faults.expired_requests += 1;
                break;
            } else {
                ctx.push(next as i32);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::SessionConfig;

    fn session() -> OffloadSession {
        OffloadSession::new(SessionConfig::default(), &[]).unwrap()
    }

    #[test]
    fn serve_rejects_empty_prompt() {
        let mut model = Gpt2Model::new(ModelConfig::d2(), 7);
        let reqs = [GenRequest::new(vec![], 4, 1)];
        let err = serve(
            &mut model,
            &reqs,
            &mut session(),
            None,
            &ServeConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("empty prompt"), "{err}");
    }

    #[test]
    fn serve_rejects_overlong_generation() {
        let cfg = ModelConfig::d2();
        let mut model = Gpt2Model::new(cfg, 7);
        let reqs = [GenRequest::new(vec![1, 2], cfg.max_seq_len, 1)];
        let err = serve(
            &mut model,
            &reqs,
            &mut session(),
            None,
            &ServeConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("context"), "{err}");
    }

    #[test]
    fn admission_policy_parses_cli_forms() {
        assert_eq!("fifo".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Fifo);
        assert_eq!(
            "latency".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::Latency
        );
        assert!("sjf".parse::<AdmissionPolicy>().is_err());
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Fifo);
        assert_eq!(AdmissionPolicy::Fifo.to_string(), "fifo");
        assert_eq!(AdmissionPolicy::Latency.to_string(), "latency");
    }

    #[test]
    fn latency_admission_reorders_waits_but_not_tokens() {
        // One long request ahead of one short one, a single batch slot:
        // FIFO makes the short request wait out the long generation;
        // latency admission runs it first. Tokens are per-request
        // deterministic either way.
        let reqs = [
            GenRequest::new(vec![5, 9, 2, 7], 6, 31),
            GenRequest::new(vec![3, 1], 2, 32),
        ];
        let mut run = |admission: AdmissionPolicy| {
            let mut model = Gpt2Model::new(ModelConfig::d2(), 7);
            let cfg = ServeConfig {
                max_batch: 1,
                admission,
                ..ServeConfig::default()
            };
            serve(&mut model, &reqs, &mut session(), None, &cfg).unwrap()
        };
        let fifo = run(AdmissionPolicy::Fifo);
        let latency = run(AdmissionPolicy::Latency);
        for (f, l) in fifo.generations.iter().zip(&latency.generations) {
            assert_eq!(f.tokens, l.tokens, "admission must not change token streams");
            assert_eq!(f.final_logits, l.final_logits);
        }
        assert_eq!(fifo.admission_waits_s[0], 0.0, "FIFO admits arrival order");
        assert!(fifo.admission_waits_s[1] > 0.0, "short request waits under FIFO");
        assert_eq!(
            latency.admission_waits_s[1], 0.0,
            "latency admission runs the short request first"
        );
        assert!(latency.admission_waits_s[0] > 0.0);
    }

    #[test]
    fn report_percentiles_and_occupancy() {
        let report = ServeReport {
            tokens: 8,
            steps: 2,
            modeled_s: 2.0,
            latencies_s: vec![0.4, 0.1, 0.3, 0.2],
            ..ServeReport::default()
        };
        assert_eq!(report.tokens_per_s(), 4.0);
        assert_eq!(report.mean_occupancy(), 4.0);
        assert_eq!(report.latency_percentile_s(0.0), 0.1);
        assert_eq!(report.latency_percentile_s(100.0), 0.4);
        assert_eq!(report.latency_percentile_s(50.0), 0.3);
    }
}
