//! XRT device handle and kernel runs.
//!
//! Mirrors the XRT host API surface the paper's initialization uses
//! (section V-A): register an xclbin (-> [`super::super::npu::NpuDevice`]
//! load_config), preload per-size instruction streams, create BOs, and
//! launch runs that execute a GEMM with explicit-sync semantics.

use crate::gemm::tiling::Tiling;
use crate::npu::config::StaticConfig;
use crate::npu::profile::DeviceProfile;
use crate::npu::{GemmReport, NpuDevice};
use crate::util::error::{Error, Result};

use super::bo::{BufferObject, SyncCost, SyncDirection};

/// Host handle to the (simulated) NPU.
pub struct XrtDevice {
    pub npu: NpuDevice,
    pub sync_cost: SyncCost,
    /// Modeled seconds spent in driver syncs, split by direction.
    pub sync_in_s: f64,
    pub sync_out_s: f64,
}

/// A completed kernel run's result.
#[derive(Debug, Clone)]
pub struct Run {
    pub report: GemmReport,
    /// Modeled instruction-stream issue seconds for this run.
    pub issue_s: f64,
}

impl XrtDevice {
    /// Open the device (power-on state; no configuration resident).
    pub fn open() -> XrtDevice {
        XrtDevice::open_with_profile(&DeviceProfile::xdna1())
    }

    /// Open the device priced as `profile`'s generation: the simulated
    /// NPU's timing and power models come from the profile. The functional
    /// datapath stays the paper's 4×4 partition regardless of target —
    /// profiles change what schedules *cost*, never what GEMMs *compute*.
    pub fn open_with_profile(profile: &DeviceProfile) -> XrtDevice {
        let mut npu = NpuDevice::new();
        npu.timing = profile.timing.clone();
        npu.power = profile.power.clone();
        XrtDevice {
            npu,
            sync_cost: SyncCost::default(),
            sync_in_s: 0.0,
            sync_out_s: 0.0,
        }
    }

    /// Register an xclbin: loads the static configuration into the array.
    /// Returns modeled reconfiguration seconds (0 if already resident).
    pub fn register_xclbin(&mut self, cfg: &StaticConfig) -> Result<f64> {
        self.npu.load_config(cfg)
    }

    /// Allocate a shared BO of `len` f32s.
    pub fn alloc_bo(&self, len: usize) -> BufferObject {
        BufferObject::new(len)
    }

    /// Sync a BO, accounting the driver cost to this device's telemetry.
    pub fn sync_bo(&mut self, bo: &mut BufferObject, dir: SyncDirection) -> f64 {
        let cost = bo.sync(dir, &self.sync_cost);
        match dir {
            SyncDirection::ToDevice => self.sync_in_s += cost,
            SyncDirection::FromDevice => self.sync_out_s += cost,
        }
        cost
    }

    /// Issue a preloaded instruction stream (minimal reconfiguration for a
    /// problem size). Returns modeled seconds.
    pub fn issue_instructions(&mut self, words: &[u32]) -> Result<f64> {
        self.npu.run_instructions(words)
    }

    /// Launch a GEMM run: device reads `a_bo`/`b_bo` (must be synced to
    /// device), writes `c_bo` (left device-dirty — the host must sync it
    /// back, like real XRT).
    pub fn run_gemm(
        &mut self,
        a_bo: &BufferObject,
        b_bo: &BufferObject,
        c_bo: &mut BufferObject,
        t: &Tiling,
    ) -> Result<Run> {
        let a_full = a_bo.device_read()?;
        if a_full.len() < t.size.m * t.size.k {
            return Err(Error::xrt(format!(
                "input BO A has {} elements, problem needs {}",
                a_full.len(),
                t.size.m * t.size.k
            )));
        }
        // BOs may be allocated at the padded size (m_padded × k); the
        // device consumes the logical M×K prefix and pads internally.
        let a = &a_full[..t.size.m * t.size.k];
        let b = b_bo.device_read()?;
        if c_bo.len() != t.size.m * t.size.n {
            return Err(Error::xrt(format!(
                "output BO has {} elements, problem needs {}",
                c_bo.len(),
                t.size.m * t.size.n
            )));
        }
        let (c, report) = self.npu.execute_gemm(a, b, t)?;
        c_bo.device_write().copy_from_slice(&c);
        Ok(Run {
            issue_s: self.npu.timing.inst_issue_s,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu;
    use crate::gemm::sizes::ProblemSize;
    use crate::npu::gemm_design;
    use crate::util::rng::Rng;

    fn full_flow(m: usize, k: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let t = Tiling::paper(ProblemSize::new(m, k, n)).unwrap();
        let mut dev = XrtDevice::open();
        dev.register_xclbin(&gemm_design::build_static_config(t.tiles))
            .unwrap();
        dev.issue_instructions(&gemm_design::build_instruction_stream(&t))
            .unwrap();

        let mut rng = Rng::new(77);
        let mut a_bo = dev.alloc_bo(m * k);
        let mut b_bo = dev.alloc_bo(k * n);
        let mut c_bo = dev.alloc_bo(m * n);
        rng.fill_normal(a_bo.map_mut(), 0.0, 1.0);
        rng.fill_normal(b_bo.map_mut(), 0.0, 1.0);
        dev.sync_bo(&mut a_bo, SyncDirection::ToDevice);
        dev.sync_bo(&mut b_bo, SyncDirection::ToDevice);
        dev.run_gemm(&a_bo, &b_bo, &mut c_bo, &t).unwrap();
        dev.sync_bo(&mut c_bo, SyncDirection::FromDevice);
        let a = a_bo.map().unwrap().to_vec();
        let b = b_bo.map().unwrap().to_vec();
        let c = c_bo.map().unwrap().to_vec();
        (a, b, c)
    }

    #[test]
    fn end_to_end_xrt_flow_is_correct() {
        let (a, b, c) = full_flow(64, 64, 128);
        let mut c_ref = vec![0.0; 64 * 128];
        cpu::gemm_bf16_ref(&a, &b, &mut c_ref, 64, 64, 128);
        for (x, y) in c.iter().zip(&c_ref) {
            assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0));
        }
    }

    #[test]
    fn unsynced_input_rejected() {
        let t = Tiling::paper(ProblemSize::new(64, 64, 128)).unwrap();
        let mut dev = XrtDevice::open();
        dev.register_xclbin(&gemm_design::build_static_config(t.tiles))
            .unwrap();
        dev.issue_instructions(&gemm_design::build_instruction_stream(&t))
            .unwrap();
        let mut a_bo = dev.alloc_bo(64 * 64);
        let b_bo = dev.alloc_bo(64 * 128);
        let mut c_bo = dev.alloc_bo(64 * 128);
        a_bo.map_mut()[0] = 1.0; // dirty, never synced
        assert!(dev.run_gemm(&a_bo, &b_bo, &mut c_bo, &t).is_err());
    }

    #[test]
    fn unsynced_output_read_rejected() {
        let t = Tiling::paper(ProblemSize::new(64, 64, 128)).unwrap();
        let mut dev = XrtDevice::open();
        dev.register_xclbin(&gemm_design::build_static_config(t.tiles))
            .unwrap();
        dev.issue_instructions(&gemm_design::build_instruction_stream(&t))
            .unwrap();
        let mut a_bo = dev.alloc_bo(64 * 64);
        let mut b_bo = dev.alloc_bo(64 * 128);
        let mut c_bo = dev.alloc_bo(64 * 128);
        dev.sync_bo(&mut a_bo, SyncDirection::ToDevice);
        dev.sync_bo(&mut b_bo, SyncDirection::ToDevice);
        dev.run_gemm(&a_bo, &b_bo, &mut c_bo, &t).unwrap();
        assert!(c_bo.map().is_err(), "must sync FromDevice first");
    }

    #[test]
    fn sync_telemetry_accumulates() {
        let mut dev = XrtDevice::open();
        let mut bo = dev.alloc_bo(1024);
        dev.sync_bo(&mut bo, SyncDirection::ToDevice);
        dev.sync_bo(&mut bo, SyncDirection::FromDevice);
        assert!(dev.sync_in_s > 0.0);
        assert!(dev.sync_out_s > 0.0);
    }

    #[test]
    fn wrong_output_size_rejected() {
        let t = Tiling::paper(ProblemSize::new(64, 64, 128)).unwrap();
        let mut dev = XrtDevice::open();
        dev.register_xclbin(&gemm_design::build_static_config(t.tiles))
            .unwrap();
        dev.issue_instructions(&gemm_design::build_instruction_stream(&t))
            .unwrap();
        let mut a_bo = dev.alloc_bo(64 * 64);
        let mut b_bo = dev.alloc_bo(64 * 128);
        let mut c_bo = dev.alloc_bo(10);
        dev.sync_bo(&mut a_bo, SyncDirection::ToDevice);
        dev.sync_bo(&mut b_bo, SyncDirection::ToDevice);
        assert!(dev.run_gemm(&a_bo, &b_bo, &mut c_bo, &t).is_err());
    }
}
