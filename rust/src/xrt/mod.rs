//! XRT — the host programming interface, in the shape of Xilinx Run Time.
//!
//! The paper's host side (section V) talks to the NPU exclusively through
//! XRT: register an xclbin, create shared buffer objects (BOs), sync them
//! between host caches and device-visible memory, and launch kernel runs
//! that feed the command processor an instruction stream. We model each of
//! those verbs; "input sync." and "output sync." in the paper's Figure 7
//! are exactly the BO sync calls accounted here.
//!
//! The explicit-sync protocol is enforced: a BO written by the host must be
//! synced `ToDevice` before a kernel may read it, and synced `FromDevice`
//! after a kernel wrote it — skipping either is an error here, where real
//! XRT would silently hand back stale data.
//!
//! ```
//! use xdna_repro::xrt::{SyncDirection, XrtDevice};
//!
//! let mut dev = XrtDevice::open();
//! let mut bo = dev.alloc_bo(16);
//! bo.map_mut()[0] = 1.0;           // host write: BO is now host-dirty
//! let modeled_s = dev.sync_bo(&mut bo, SyncDirection::ToDevice);
//! assert!(modeled_s > 0.0);        // driver sync cost is modeled
//! assert_eq!(bo.map().unwrap()[0], 1.0);
//! ```

pub mod bo;
pub mod device;

pub use bo::{BufferObject, SyncDirection};
pub use device::{Run, XrtDevice};
