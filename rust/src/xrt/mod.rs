//! XRT — the host programming interface, in the shape of Xilinx Run Time.
//!
//! The paper's host side (section V) talks to the NPU exclusively through
//! XRT: register an xclbin, create shared buffer objects (BOs), sync them
//! between host caches and device-visible memory, and launch kernel runs
//! that feed the command processor an instruction stream. We model each of
//! those verbs; "input sync." and "output sync." in the paper's Figure 7
//! are exactly the BO sync calls accounted here.

pub mod bo;
pub mod device;

pub use bo::{BufferObject, SyncDirection};
pub use device::{Run, XrtDevice};
