//! Shared buffer objects (BOs).
//!
//! A BO is host-allocated memory visible to the NPU through the unified L3.
//! The host must explicitly sync a BO to the device before a kernel reads
//! it and from the device after a kernel writes it (cache maintenance +
//! driver bookkeeping). The sync cost is the per-invocation overhead the
//! paper identifies as unavoidable ("Input sync." / "output sync." ...
//! dispatch overheads incurred by the XDNA driver", Figure 7).

use crate::util::error::{Error, Result};

/// Direction of an explicit BO sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncDirection {
    ToDevice,
    FromDevice,
}

/// State tracking for coherence bugs: who wrote last.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Coherence {
    /// Host writes not yet visible to device.
    HostDirty,
    /// Device writes not yet visible to host.
    DeviceDirty,
    /// In sync.
    Clean,
}

/// A shared f32 buffer object.
#[derive(Debug)]
pub struct BufferObject {
    data: Vec<f32>,
    state: Coherence,
    /// Telemetry.
    pub syncs_to_device: u64,
    pub syncs_from_device: u64,
}

impl BufferObject {
    /// Allocate a zeroed BO of `len` f32 elements.
    pub fn new(len: usize) -> BufferObject {
        BufferObject {
            data: vec![0.0; len],
            state: Coherence::Clean,
            syncs_to_device: 0,
            syncs_from_device: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Host-side write access (marks the BO host-dirty).
    pub fn map_mut(&mut self) -> &mut [f32] {
        self.state = Coherence::HostDirty;
        &mut self.data
    }

    /// Host-side read access; errors if the device wrote and the host has
    /// not synced from device (a real coherence bug XRT users hit).
    pub fn map(&self) -> Result<&[f32]> {
        if self.state == Coherence::DeviceDirty {
            return Err(Error::xrt(
                "reading BO with un-synced device writes (missing sync FromDevice)",
            ));
        }
        Ok(&self.data)
    }

    /// Device-side read access; errors if host writes were never synced.
    pub(crate) fn device_read(&self) -> Result<&[f32]> {
        if self.state == Coherence::HostDirty {
            return Err(Error::xrt(
                "device reading BO with un-synced host writes (missing sync ToDevice)",
            ));
        }
        Ok(&self.data)
    }

    /// Device-side write access (marks device-dirty).
    pub(crate) fn device_write(&mut self) -> &mut [f32] {
        self.state = Coherence::DeviceDirty;
        &mut self.data
    }

    /// Explicit sync; returns the modeled driver cost in seconds
    /// (accounted by the caller against the Figure-7 stages).
    pub fn sync(&mut self, dir: SyncDirection, cost_model: &SyncCost) -> f64 {
        match dir {
            SyncDirection::ToDevice => {
                self.syncs_to_device += 1;
                if self.state == Coherence::HostDirty {
                    self.state = Coherence::Clean;
                }
                cost_model.cost_s(self.len() * 4, dir)
            }
            SyncDirection::FromDevice => {
                self.syncs_from_device += 1;
                if self.state == Coherence::DeviceDirty {
                    self.state = Coherence::Clean;
                }
                cost_model.cost_s(self.len() * 4, dir)
            }
        }
    }
}

/// Sync cost model: fixed driver overhead + per-byte cache-maintenance.
#[derive(Debug, Clone)]
pub struct SyncCost {
    pub fixed_to_dev_s: f64,
    pub fixed_from_dev_s: f64,
    /// Cache flush/invalidate throughput (bytes/s).
    pub bytes_per_s: f64,
}

impl Default for SyncCost {
    fn default() -> Self {
        SyncCost {
            fixed_to_dev_s: 60e-6,
            fixed_from_dev_s: 45e-6,
            bytes_per_s: 40e9,
        }
    }
}

impl SyncCost {
    pub fn cost_s(&self, bytes: usize, dir: SyncDirection) -> f64 {
        let fixed = match dir {
            SyncDirection::ToDevice => self.fixed_to_dev_s,
            SyncDirection::FromDevice => self.fixed_from_dev_s,
        };
        fixed + bytes as f64 / self.bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coherence_protocol_enforced() {
        let mut bo = BufferObject::new(16);
        bo.map_mut()[0] = 1.0;
        // Device read before sync is a bug.
        assert!(bo.device_read().is_err());
        bo.sync(SyncDirection::ToDevice, &SyncCost::default());
        assert_eq!(bo.device_read().unwrap()[0], 1.0);
        // Device writes; host read before sync is a bug.
        bo.device_write()[1] = 2.0;
        assert!(bo.map().is_err());
        bo.sync(SyncDirection::FromDevice, &SyncCost::default());
        assert_eq!(bo.map().unwrap()[1], 2.0);
    }

    #[test]
    fn sync_costs_scale_with_size() {
        let cm = SyncCost::default();
        let small = cm.cost_s(1024, SyncDirection::ToDevice);
        let large = cm.cost_s(100 << 20, SyncDirection::ToDevice);
        assert!(large > small);
        assert!(small >= cm.fixed_to_dev_s);
    }

    #[test]
    fn telemetry_counts_syncs() {
        let mut bo = BufferObject::new(4);
        let cm = SyncCost::default();
        bo.sync(SyncDirection::ToDevice, &cm);
        bo.sync(SyncDirection::ToDevice, &cm);
        bo.sync(SyncDirection::FromDevice, &cm);
        assert_eq!(bo.syncs_to_device, 2);
        assert_eq!(bo.syncs_from_device, 1);
    }
}
