//! Energy metering with the paper's 4 Hz sampling structure.
//!
//! The paper reads the battery driver's instantaneous power every 250 ms
//! and integrates. We synthesize the same trace from the profile's power
//! states over modeled time; FLOP/Ws then falls out identically.

use crate::npu::energy::NpuPower;

use super::profiles::PowerProfile;

/// Sampling period (the paper polls every 1/4 s).
pub const SAMPLE_PERIOD_S: f64 = 0.25;

/// A power meter for one measured interval.
#[derive(Debug, Clone)]
pub struct PowerMeter {
    profile: PowerProfile,
    /// Sampled (t, watts) trace, like the polled driver file.
    pub samples: Vec<(f64, f64)>,
}

impl PowerMeter {
    pub fn new(profile: PowerProfile) -> PowerMeter {
        PowerMeter {
            profile,
            samples: Vec::new(),
        }
    }

    /// Integrate one epoch of modeled duration `epoch_s`, drawing the
    /// profile's power for the given mode. Returns Joules and appends the
    /// 4 Hz samples to the trace.
    pub fn integrate_epoch(&mut self, epoch_s: f64, offloaded: bool) -> f64 {
        let watts = if offloaded {
            self.profile.platform_offload_w + self.profile.npu_active_w
        } else {
            self.profile.platform_cpu_busy_w
        };
        let t0 = self.samples.last().map(|(t, _)| *t).unwrap_or(0.0);
        let mut t = 0.0;
        while t < epoch_s {
            self.samples.push((t0 + t, watts));
            t += SAMPLE_PERIOD_S;
        }
        watts * epoch_s
    }

    /// Integrate one *offloaded* epoch with the NPU charged by column
    /// state instead of the flat `npu_active_w` assumption of
    /// [`Self::integrate_epoch`]: the platform draws its offload power for
    /// the whole epoch, while the NPU pays active draw only for each
    /// column's busy seconds, the idle floor for the rest of the window,
    /// and reconfiguration draw for the barriers
    /// ([`NpuPower::window_energy_j`]). `col_busy_s` is the epoch's
    /// per-column device-busy delta (the session timeline's growth).
    /// Returns Joules and appends 4 Hz samples at the epoch's mean power.
    pub fn integrate_epoch_offloaded(
        &mut self,
        epoch_s: f64,
        npu: &NpuPower,
        col_busy_s: &[f64],
        reconfig_s: f64,
    ) -> f64 {
        let energy = self.profile.platform_offload_w * epoch_s
            + npu.window_energy_j(col_busy_s, epoch_s, reconfig_s);
        let watts = if epoch_s > 0.0 { energy / epoch_s } else { 0.0 };
        let t0 = self.samples.last().map(|(t, _)| *t).unwrap_or(0.0);
        let mut t = 0.0;
        while t < epoch_s {
            self.samples.push((t0 + t, watts));
            t += SAMPLE_PERIOD_S;
        }
        energy
    }

    /// Mean power over the trace (what the paper reports dividing by).
    pub fn mean_watts(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|(_, w)| w).sum::<f64>() / self.samples.len() as f64
    }
}

/// FLOP per Watt-second (the paper's efficiency metric).
pub fn flops_per_ws(flops: u64, energy_j: f64) -> f64 {
    flops as f64 / energy_j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integration_is_power_times_time() {
        let mut m = PowerMeter::new(PowerProfile::mains());
        let e = m.integrate_epoch(2.0, false);
        assert!((e - 2.0 * PowerProfile::mains().platform_cpu_busy_w).abs() < 1e-9);
        assert_eq!(m.samples.len(), 8);
    }

    #[test]
    fn offloaded_draws_less() {
        let mut a = PowerMeter::new(PowerProfile::mains());
        let mut b = PowerMeter::new(PowerProfile::mains());
        let e_cpu = a.integrate_epoch(1.0, false);
        let e_npu = b.integrate_epoch(1.0, true);
        assert!(e_npu < e_cpu);
    }

    #[test]
    fn efficiency_metric() {
        assert!((flops_per_ws(100, 50.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn offloaded_epoch_charges_npu_by_column_state() {
        let npu = NpuPower::default();
        let p = PowerProfile::mains();
        // One of four columns busy half a 2 s window: far less NPU draw
        // than the flat array-active assumption.
        let mut col = PowerMeter::new(p.clone());
        let e_col = col.integrate_epoch_offloaded(2.0, &npu, &[1.0, 0.0, 0.0, 0.0], 0.0);
        let want = p.platform_offload_w * 2.0
            + npu.active_w * 1.0
            + npu.idle_w * (4.0 * 2.0 - 1.0);
        assert!((e_col - want).abs() < 1e-9);
        assert_eq!(col.samples.len(), 8);

        let mut flat = PowerMeter::new(p);
        let e_flat = flat.integrate_epoch(2.0, true);
        assert!(e_col < e_flat, "mostly idle columns must cost less than flat active");

        // Reconfiguration barriers are priced, not free.
        let mut rc = PowerMeter::new(PowerProfile::mains());
        let e_rc = rc.integrate_epoch_offloaded(2.0, &npu, &[1.0, 0.0, 0.0, 0.0], 0.5);
        assert!((e_rc - e_col - npu.reconfig_w * 0.5).abs() < 1e-9);
    }
}
