//! Platform power/performance profiles: mains vs battery.
//!
//! Calibration targets (paper section VII): GEMM speedups avg 3.1× fwd /
//! 2.8× bwd, max 4.2×, min 1.8×; end-to-end throughput 1.7× (mains) and
//! 1.2× (battery); energy efficiency 1.4× (battery). Each constant below
//! is a named, documented knob; EXPERIMENTS.md reports the resulting
//! paper-vs-model numbers.

use crate::model::config::ModelConfig;

/// One power/performance operating point of the laptop.
#[derive(Debug, Clone)]
pub struct PowerProfile {
    pub name: &'static str,
    /// Effective llm.c CPU GEMM throughput (FLOP/s). The 7940HS sustains
    /// ~8 Zen4 cores × AVX-512 f32 FMA; llm.c's loop nest reaches a good
    /// fraction of that on mains and throttles on battery.
    pub cpu_gemm_flops: f64,
    /// Effective CPU throughput for the non-GEMM ops (FLOP/s). llm.c's
    /// encoder/layernorm/attention/residual loops are memory-bound scalar
    /// code: their effective FLOP rate is two orders of magnitude below
    /// the GEMM loop nest (this is why the paper's end-to-end speedup is
    /// 1.7x even though GEMMs alone speed up ~3x).
    pub cpu_other_flops: f64,
    /// Multiplier on modeled NPU device seconds (battery caps the NPU/DDR
    /// clocks much harder than the CPU's, which is why the paper's
    /// end-to-end speedup drops from 1.7× to 1.2× on battery).
    pub npu_time_scale: f64,
    /// Whole-platform power while the CPU crunches GEMMs (W).
    pub platform_cpu_busy_w: f64,
    /// Whole-platform power while only the non-GEMM CPU work runs and the
    /// NPU handles GEMMs (W) — the CPU is still busy, just less so.
    pub platform_offload_w: f64,
    /// NPU's own additional draw while active (W).
    pub npu_active_w: f64,
}

impl PowerProfile {
    /// Plugged in, performance governor (paper's "(M)" bars).
    pub fn mains() -> PowerProfile {
        PowerProfile {
            name: "mains",
            cpu_gemm_flops: 160e9,
            cpu_other_flops: 1.5e9,
            npu_time_scale: 1.0,
            platform_cpu_busy_w: 45.0,
            platform_offload_w: 32.0,
            npu_active_w: 2.5,
        }
    }

    /// On battery (paper's "(B)" bars): CPU mildly throttled, NPU/DDR
    /// heavily throttled, everything drawing less.
    pub fn battery() -> PowerProfile {
        PowerProfile {
            name: "battery",
            cpu_gemm_flops: 135e9,
            cpu_other_flops: 1.35e9,
            npu_time_scale: 3.3,
            platform_cpu_busy_w: 28.0,
            platform_offload_w: 21.5,
            npu_active_w: 1.8,
        }
    }

    pub fn by_name(name: &str) -> Option<PowerProfile> {
        match name {
            "mains" | "m" => Some(Self::mains()),
            "battery" | "b" => Some(Self::battery()),
            _ => None,
        }
    }

    /// Modeled CPU seconds of one epoch (one training step at B,T).
    /// With `offloaded` the GEMM portion is excluded (it runs on the NPU;
    /// the trainer adds the engine's modeled device seconds scaled by
    /// `npu_time_scale`).
    pub fn modeled_epoch_s(
        &self,
        cfg: &ModelConfig,
        b: usize,
        t: usize,
        offloaded: bool,
    ) -> f64 {
        let table = crate::model::flops::table(cfg, b, t);
        let mut s = 0.0f64;
        for op in &table {
            let fl = (op.forward + op.backward) as f64;
            if op.op == "matmul" {
                if !offloaded {
                    s += fl / self.cpu_gemm_flops;
                }
            } else {
                s += fl / self.cpu_other_flops;
            }
        }
        s
    }

    /// Modeled CPU seconds of one *standalone* GEMM of `flops` FLOPs
    /// (the Figure 6 CPU bars).
    pub fn cpu_gemm_s(&self, flops: u64) -> f64 {
        flops as f64 / self.cpu_gemm_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn battery_is_slower_and_cooler() {
        let m = PowerProfile::mains();
        let b = PowerProfile::battery();
        assert!(b.cpu_gemm_flops < m.cpu_gemm_flops);
        assert!(b.npu_time_scale > m.npu_time_scale);
        assert!(b.platform_cpu_busy_w < m.platform_cpu_busy_w);
    }

    #[test]
    fn offloaded_epoch_excludes_gemm_time() {
        let p = PowerProfile::mains();
        let cfg = ModelConfig::d12();
        let full = p.modeled_epoch_s(&cfg, 4, 64, false);
        let off = p.modeled_epoch_s(&cfg, 4, 64, true);
        assert!(full > 2.0 * off, "GEMMs dominate: {full} vs {off}");
    }

    #[test]
    fn by_name() {
        assert!(PowerProfile::by_name("mains").is_some());
        assert!(PowerProfile::by_name("battery").is_some());
        assert!(PowerProfile::by_name("solar").is_none());
    }
}
