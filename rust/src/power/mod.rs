//! Power and energy modeling (the paper's Figure 9 axis).
//!
//! The paper polls `/sys/class/power_supply/BAT0/power_now` at 4 Hz while
//! training on mains vs battery. Without the laptop, we model the
//! platform's power states ([`profiles`]) and integrate them over modeled
//! time ([`meter`]), keeping the same 4 Hz sampling structure so the
//! measurement pipeline (sampling → trace → mean power → FLOP/Ws) is
//! exercised end to end.
//!
//! ```
//! use xdna_repro::power::PowerProfile;
//!
//! // Battery throttles the NPU/DDR clocks much harder than the CPU's
//! // (the paper's 1.7x -> 1.2x end-to-end drop).
//! let mains = PowerProfile::mains();
//! let battery = PowerProfile::battery();
//! assert!(battery.npu_time_scale > mains.npu_time_scale);
//! assert!(battery.platform_cpu_busy_w < mains.platform_cpu_busy_w);
//! ```

pub mod meter;
pub mod profiles;

pub use meter::PowerMeter;
pub use profiles::PowerProfile;
