//! # xdna-repro
//!
//! Reproduction of *"Unlocking the AMD Neural Processing Unit for ML Training
//! on the Client Using Bare-Metal-Programming Tools"* (Rösti & Franz, 2025).
//!
//! The paper fine-tunes GPT-2 (124M) on a laptop by offloading GEMM
//! operations from a pure-C training loop (`llm.c`) onto the AMD XDNA NPU,
//! programmed bare-metal through the IRON tool-flow. This crate rebuilds the
//! entire system as a three-layer Rust + JAX + Pallas stack with the NPU
//! hardware replaced by a functional + cycle-model simulator:
//!
//! * [`npu`] — XDNA NPU simulator: 4x4 compute-core grid, memory cores, shim
//!   cores, DMAs with layout transforms, switch-box streams, hardware locks,
//!   command processor with an instruction-stream ISA, VMAC micro-kernel,
//!   cycle/energy model.
//! * [`xrt`] — host runtime in the shape of Xilinx Run Time: devices,
//!   buffer objects with explicit sync, kernel runs.
//! * [`gemm`] — tiling math, bf16 substrate, the CPU (llm.c-style) GEMM
//!   baseline, and the problem-size registry of GPT-2 124M.
//! * [`coordinator`] — the paper's contribution as a layered
//!   record→schedule→execute offload API:
//!   [`coordinator::device::ComputeDevice`] (numerics: simulator, CPU bf16
//!   oracle, or PJRT artifacts), [`coordinator::session::OffloadSession`]
//!   (per-size registry, k-deep submission ring, fixed or cost-model-chosen
//!   N-dimension sharding, session-scoped tickets),
//!   [`coordinator::plan::StepPlan`] (record a whole training step, then
//!   schedule it at once — whole-step batching + weight-staging prefetch,
//!   with [`coordinator::plan::PlanCache`] freezing the schedule for
//!   replay, in process and on disk),
//!   [`coordinator::scheduler::Scheduler`] (reconfig-aware batching), and
//!   [`coordinator::executor`] (the background step executor: cached-step
//!   replays drain their device-stage loop off the trainer's thread, so
//!   staging + kernels overlap the model's CPU work in measured
//!   wallclock). The PR-1 `GemmOffloadEngine` remains as a thin shim over
//!   a depth-1/2 session.
//! * [`model`] — an llm.c port: GPT-2 forward/backward/AdamW in pure Rust
//!   with every matmul dispatched through the offload engine.
//! * [`runtime`] — the artifact manifest ABI and (behind the `pjrt` cargo
//!   feature) the PJRT loader for the JAX/Pallas AOT artifacts
//!   (`artifacts/*.hlo.txt`) used as the numerical oracle and the
//!   whole-model train step.
//! * [`power`] — battery/mains power-supply model and energy metering.
//! * [`bench`] — harness that regenerates every figure/table of the paper.
//! * [`util`] — substrate the offline environment lacks: PRNG, JSON,
//!   thread pool, stats, timers, CLI parsing.
//!
//! # Quickstart
//!
//! Offload one GEMM through the full engine → XRT → simulated-NPU stack:
//!
//! ```
//! use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine, InputLayout};
//! use xdna_repro::gemm::sizes::ProblemSize;
//!
//! let size = ProblemSize::new(64, 64, 128);
//! let mut engine = GemmOffloadEngine::new(EngineConfig::default(), &[size])?;
//! let a = vec![1.0f32; size.m * size.k];
//! let b = vec![0.5f32; size.k * size.n];
//! let mut c = vec![0.0f32; size.m * size.n];
//! let stats = engine.gemm(size, &a, &b, InputLayout::RowMajor, &mut c)?;
//! assert!((c[0] - 32.0).abs() < 1e-3); // 64 * 1.0 * 0.5
//! assert!(stats.modeled_total_s() > 0.0);
//! # Ok::<(), xdna_repro::Error>(())
//! ```

pub mod bench;
pub mod coordinator;
pub mod gemm;
pub mod model;
pub mod power;
pub mod npu;
pub mod runtime;
pub mod xrt;
pub mod util;

pub use util::error::{Error, Result};
