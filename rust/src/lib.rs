//! # xdna-repro
//!
//! Reproduction of *"Unlocking the AMD Neural Processing Unit for ML Training
//! on the Client Using Bare-Metal-Programming Tools"* (Rösti & Franz, 2025).
//!
//! The paper fine-tunes GPT-2 (124M) on a laptop by offloading GEMM
//! operations from a pure-C training loop (`llm.c`) onto the AMD XDNA NPU,
//! programmed bare-metal through the IRON tool-flow. This crate rebuilds the
//! entire system as a three-layer Rust + JAX + Pallas stack with the NPU
//! hardware replaced by a functional + cycle-model simulator:
//!
//! * [`npu`] — XDNA NPU simulator: 4x4 compute-core grid, memory cores, shim
//!   cores, DMAs with layout transforms, switch-box streams, hardware locks,
//!   command processor with an instruction-stream ISA, VMAC micro-kernel,
//!   cycle/energy model.
//! * [`xrt`] — host runtime in the shape of Xilinx Run Time: devices,
//!   buffer objects with explicit sync, kernel runs.
//! * [`gemm`] — tiling math, bf16 substrate, the CPU (llm.c-style) GEMM
//!   baseline, and the problem-size registry of GPT-2 124M.
//! * [`coordinator`] — the paper's contribution: the minimal-reconfiguration
//!   GEMM offload engine (Section V/VI of the paper).
//! * [`model`] — an llm.c port: GPT-2 forward/backward/AdamW in pure Rust
//!   with every matmul dispatched through the offload engine.
//! * [`runtime`] — PJRT loader for the JAX/Pallas AOT artifacts
//!   (`artifacts/*.hlo.txt`) used as the numerical oracle and the
//!   whole-model train step.
//! * [`power`] — battery/mains power-supply model and energy metering.
//! * [`bench`] — harness that regenerates every figure/table of the paper.
//! * [`util`] — substrate the offline environment lacks: PRNG, JSON,
//!   thread pool, stats, timers, CLI parsing.

pub mod bench;
pub mod coordinator;
pub mod gemm;
pub mod model;
pub mod power;
pub mod npu;
pub mod runtime;
pub mod xrt;
pub mod util;

pub use util::error::{Error, Result};
