//! Regenerates the section VII-A numerical-accuracy comparison.
//! XDNA_REPRO_BENCH_FULL=1 measures all 12 sizes (slower).
use xdna_repro::bench::accuracy;

fn main() {
    let full = std::env::var("XDNA_REPRO_BENCH_FULL").is_ok();
    accuracy::print(full).unwrap();
}
