//! Regenerates paper Figure 6: per-problem-size GEMM runtime, CPU vs NPU.
//! Cost-model rows for the full 124M inventory plus measured wallclock of
//! the real engine invocation path on a subset of sizes.
use xdna_repro::bench::fig6;
use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine, InputLayout};
use xdna_repro::gemm::sizes::ProblemSize;
use xdna_repro::power::profiles::PowerProfile;
use xdna_repro::util::bench::{print_header, print_row, run, BenchConfig};

fn main() {
    fig6::print(&PowerProfile::mains());

    print_header("Figure 6 (wallclock): engine invocation path on this machine");
    let cfg = BenchConfig::from_env();
    let sizes = [
        ProblemSize::new(256, 768, 768),
        ProblemSize::new(256, 768, 2304),
        ProblemSize::new(768, 256, 768),
    ];
    let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &sizes).unwrap();
    for size in sizes {
        let a = vec![0.5f32; size.m * size.k];
        let b = vec![0.25f32; size.k * size.n];
        let mut c = vec![0.0f32; size.m * size.n];
        let r = run(&format!("npu-sim {size}"), &cfg, || {
            eng.gemm(size, &a, &b, InputLayout::RowMajor, &mut c).unwrap();
        });
        print_row(&r);
        let mut c2 = vec![0.0f32; size.m * size.n];
        let r2 = run(&format!("cpu     {size}"), &cfg, || {
            xdna_repro::gemm::cpu::gemm_f32(&a, &b, &mut c2, size.m, size.k, size.n);
        });
        print_row(&r2);
    }
}
