//! Regenerates paper Figure 8: epoch runtime by op, CPU vs CPU+NPU.
//! Modeled 124M rows plus a real measured d4 epoch on both backends.
use xdna_repro::bench::fig8;
use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine};
use xdna_repro::model::model::OPS;
use xdna_repro::model::trainer::{train_synthetic, TrainBackend, TrainConfig};
use xdna_repro::model::ModelConfig;
use xdna_repro::power::profiles::PowerProfile;

fn main() {
    fig8::print(&PowerProfile::mains());
    fig8::print(&PowerProfile::battery());

    println!("\n=== Figure 8 (wallclock): real d4 epoch per-op split on this machine ===");
    let tc = TrainConfig {
        batch: 4,
        seq: 64,
        epochs: 2,
        steps_per_epoch: 2,
        ..Default::default()
    };
    for (label, npu) in [("CPU", false), ("CPU+NPU", true)] {
        let cfg = ModelConfig::d4();
        let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[]).unwrap();
        let mut backend = if npu {
            TrainBackend::CpuNpu(&mut eng)
        } else {
            TrainBackend::Cpu
        };
        // train_synthetic constructs its own model; measure via op timers of
        // a local model instead.
        let corpus = xdna_repro::model::data::synthetic_corpus(cfg.vocab_size, 4 * (4 * 64 + 1), 9);
        let mut loader = xdna_repro::model::data::DataLoader::new(corpus, 4, 64).unwrap();
        let mut model = xdna_repro::model::Gpt2Model::new(cfg, 9);
        let stats =
            xdna_repro::model::trainer::train(&mut model, &mut loader, &mut backend, &tc).unwrap();
        println!("--- {label} (epoch wall {:.1} ms) ---", stats[1].wall_s * 1e3);
        for op in OPS {
            println!(
                "{:<12} {:>10.2} ms",
                op,
                model.op_timers.get(op).as_secs_f64() * 1e3
            );
        }
    }
}
