//! Hot-path micro benchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! engine invocation overhead, parallel transpose, CPU GEMM kernel,
//! simulator exact datapath, and instruction-stream encode/decode.
use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine, InputLayout};
use xdna_repro::coordinator::transpose::transpose;
use xdna_repro::gemm::cpu;
use xdna_repro::gemm::sizes::ProblemSize;
use xdna_repro::gemm::tiling::Tiling;
use xdna_repro::npu::gemm_design::{build_instruction_stream, build_instructions};
use xdna_repro::npu::isa::{decode, encode};
use xdna_repro::util::bench::{print_header, print_row, run, BenchConfig};
use xdna_repro::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();

    print_header("engine invocation overhead (64x64x128, registry hit)");
    let size = ProblemSize::new(64, 64, 128);
    let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &[size]).unwrap();
    let a = vec![1.0f32; size.m * size.k];
    let b = vec![1.0f32; size.k * size.n];
    let mut c = vec![0.0f32; size.m * size.n];
    print_row(&run("engine.gemm 64x64x128", &cfg, || {
        eng.gemm(size, &a, &b, InputLayout::RowMajor, &mut c).unwrap();
    }));

    print_header("parallel blocked transpose");
    let mut rng = Rng::new(1);
    for (r, cdim) in [(768usize, 768usize), (2304, 768), (3072, 768)] {
        let mut src = vec![0.0f32; r * cdim];
        rng.fill_normal(&mut src, 0.0, 1.0);
        let mut dst = vec![0.0f32; r * cdim];
        print_row(&run(&format!("transpose {r}x{cdim}"), &cfg, || {
            transpose(&src, &mut dst, r, cdim);
        }));
    }

    print_header("CPU GEMM baseline (llm.c loop nest)");
    for s in [ProblemSize::new(256, 768, 768), ProblemSize::new(256, 768, 2304)] {
        let a = vec![0.5f32; s.m * s.k];
        let b = vec![0.25f32; s.k * s.n];
        let mut c = vec![0.0f32; s.m * s.n];
        print_row(&run(&format!("cpu gemm {s}"), &cfg, || {
            cpu::gemm_f32(&a, &b, &mut c, s.m, s.k, s.n);
        }));
    }

    print_header("simulator exact VMAC datapath (128x128x128)");
    {
        use xdna_repro::npu::{prepare_device, Fidelity, NpuDevice};
        let t = Tiling::paper(ProblemSize::new(128, 128, 128)).unwrap();
        let mut dev = NpuDevice::new();
        prepare_device(&mut dev, &t).unwrap();
        dev.fidelity = Fidelity::Exact;
        let a = vec![0.5f32; 128 * 128];
        let b = vec![0.25f32; 128 * 128];
        print_row(&run("exact vmac 128^3", &cfg, || {
            dev.execute_gemm(&a, &b, &t).unwrap();
        }));
    }

    print_header("instruction stream encode/decode");
    let t = Tiling::paper(ProblemSize::new(256, 768, 2304)).unwrap();
    let insts = build_instructions(&t);
    print_row(&run("encode stream", &cfg, || {
        std::hint::black_box(encode(&insts));
    }));
    let words = build_instruction_stream(&t);
    print_row(&run("decode stream", &cfg, || {
        std::hint::black_box(decode(&words).unwrap());
    }));
}
