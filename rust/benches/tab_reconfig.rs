//! Regenerates the section VII-A reconfiguration ablation (3.5x claim).
use xdna_repro::bench::reconfig;

fn main() {
    reconfig::print().unwrap();
}
