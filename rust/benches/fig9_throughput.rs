//! Regenerates paper Figure 9: throughput + energy efficiency bars.
use xdna_repro::bench::fig9;

fn main() {
    fig9::print();
}
