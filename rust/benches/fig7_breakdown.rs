//! Regenerates paper Figure 7: offloaded-GEMM stage breakdown — modeled
//! epoch totals plus real measured stage shares from the engine.
use xdna_repro::bench::fig7;
use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine, InputLayout, STAGES};
use xdna_repro::gemm::sizes::ProblemSize;
use xdna_repro::power::profiles::PowerProfile;

fn main() {
    fig7::print(&PowerProfile::mains());

    println!("\n=== Figure 7 (wallclock): measured engine stage shares ===");
    let sizes = [
        ProblemSize::new(256, 768, 768),
        ProblemSize::new(256, 768, 2304),
        ProblemSize::new(256, 2304, 768),
    ];
    let mut eng = GemmOffloadEngine::new(EngineConfig::default(), &sizes).unwrap();
    for _ in 0..5 {
        for size in sizes {
            let a = vec![0.5f32; size.m * size.k];
            let b = vec![0.25f32; size.n * size.k]; // N x K: forces transpose
            let mut c = vec![0.0f32; size.m * size.n];
            eng.gemm(size, &a, &b, InputLayout::Transposed, &mut c).unwrap();
        }
    }
    let total = eng.stages.total().as_secs_f64();
    for s in STAGES {
        let t = eng.stages.get(s).as_secs_f64();
        println!("{:<14} {:>10.3} ms ({:>5.1}%)", s, t * 1e3, 100.0 * t / total);
    }
}
