//! Overlapped-vs-serial offload schedule: the modeled epoch-level report
//! plus measured runs of the real offload session at several ring depths
//! and shard counts over a GPT-2-shaped GEMM stream, and the recorded
//! step-plan schedule (whole-stream batching + weight prefetch) over the
//! same stream.
use xdna_repro::bench::pipeline;
use xdna_repro::coordinator::plan::{PlanOp, StepPlan};
use xdna_repro::coordinator::session::{
    GemmOp, InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
    Ticket,
};
use xdna_repro::coordinator::{SchedulePolicy, STAGES};
use xdna_repro::gemm::sizes::ProblemSize;
use xdna_repro::power::profiles::PowerProfile;
use xdna_repro::util::rng::Rng;

fn run_stream(depth: usize, shards: usize, sizes: &[ProblemSize], rounds: usize) -> OffloadSession {
    let mut sess = OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards: ShardPolicy::Fixed(Shards(shards)),
            ..Default::default()
        },
        sizes,
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .map(|s| {
            let mut a = vec![0.0f32; s.m * s.k];
            let mut b_t = vec![0.0f32; s.n * s.k]; // N x K: forces transpose
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b_t, 0.0, 0.1);
            (a, b_t)
        })
        .collect();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..rounds {
        let mut pending: Vec<(usize, Ticket)> = Vec::new();
        for (i, (size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            if pending.len() == depth {
                let (j, t) = pending.remove(0);
                sess.wait(t, &mut outs[j]).unwrap();
            }
            let t = sess
                .submit(
                    &GemmOp::new(*size).with_b_layout(InputLayout::Transposed),
                    a,
                    b_t,
                )
                .unwrap();
            pending.push((i, t));
        }
        for (j, t) in pending {
            sess.wait(t, &mut outs[j]).unwrap();
        }
    }
    sess
}

fn main() {
    // Modeled epoch-level schedule for the full 124M GEMM stream.
    pipeline::print(&PowerProfile::mains());
    pipeline::print(&PowerProfile::battery());

    // Measured session runs over a trio of forward sizes.
    let sizes = [
        ProblemSize::new(256, 768, 768),
        ProblemSize::new(256, 768, 2304),
        ProblemSize::new(256, 2304, 768),
    ];
    println!(
        "\n=== Measured session: ring depth x shards over {} forward sizes ===",
        sizes.len()
    );
    for (depth, shards) in [(1, 1), (2, 1), (4, 1), (2, 4)] {
        let sess = run_stream(depth, shards, &sizes, 5);
        println!("\n-- depth {depth}, {shards} shard(s) --");
        let total = sess.stages.total().as_secs_f64();
        for s in STAGES {
            let t = sess.stages.get(s).as_secs_f64();
            println!("{:<14} {:>10.3} ms ({:>5.1}%)", s, t * 1e3, 100.0 * t / total);
        }
        println!(
            "modeled: serial {:.3} ms, overlapped {:.3} ms, hidden {:.3} ms ({:.1}%)",
            sess.pipeline.serial_s() * 1e3,
            sess.pipeline.makespan_s() * 1e3,
            sess.pipeline.hidden_s() * 1e3,
            100.0 * sess.pipeline.hidden_s() / sess.pipeline.serial_s()
        );
    }

    // Recorded step plan over the same stream: the scheduler sees all
    // rounds at once (whole-step batching) and prefetches each next op's
    // B staging under the current kernel.
    let mut sess = OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(4),
            schedule: SchedulePolicy::BatchBySize,
            shards: ShardPolicy::Auto,
            ..Default::default()
        },
        &sizes,
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .map(|s| {
            let mut a = vec![0.0f32; s.m * s.k];
            let mut b_t = vec![0.0f32; s.n * s.k];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b_t, 0.0, 0.1);
            (a, b_t)
        })
        .collect();
    let mut plan = StepPlan::new();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..5 {
        for ((size, (a, b_t)), out) in sizes.iter().zip(&inputs).zip(outs.iter_mut()) {
            let op = PlanOp::new(*size)
                .with_b_layout(InputLayout::Transposed)
                .prefetchable_b(true);
            sess.record_gemm(&mut plan, &op, a, b_t, out).unwrap();
        }
    }
    let report = sess.execute(&mut plan).unwrap();
    println!(
        "\n-- recorded step plan (depth 4, shards auto, BatchBySize) --\n\
         {} ops, {} reconfigs, {} prefetched; serial {:.3} ms, scheduled {:.3} ms, \
         hidden {:.3} ms",
        report.stats.len(),
        report.reconfigs,
        report.prefetched,
        report.serial_growth_s * 1e3,
        report.makespan_growth_s * 1e3,
        report.hidden_growth_s() * 1e3
    );
}
