//! Overlapped-vs-serial offload schedule: the modeled epoch-level report
//! plus a measured run of the real engine in both execution modes over a
//! GPT-2-shaped GEMM stream.
use xdna_repro::bench::pipeline;
use xdna_repro::coordinator::engine::{
    EngineConfig, ExecMode, GemmOffloadEngine, InputLayout, STAGES,
};
use xdna_repro::gemm::sizes::ProblemSize;
use xdna_repro::power::profiles::PowerProfile;
use xdna_repro::util::rng::Rng;

fn run_stream(mode: ExecMode, sizes: &[ProblemSize], rounds: usize) -> GemmOffloadEngine {
    let mut eng = GemmOffloadEngine::new(
        EngineConfig {
            mode,
            ..Default::default()
        },
        sizes,
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .map(|s| {
            let mut a = vec![0.0f32; s.m * s.k];
            let mut b_t = vec![0.0f32; s.n * s.k]; // N×K: forces transpose
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b_t, 0.0, 0.1);
            (a, b_t)
        })
        .collect();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..rounds {
        match mode {
            ExecMode::Serial => {
                for ((size, (a, b_t)), c) in sizes.iter().zip(&inputs).zip(&mut outs) {
                    eng.gemm(*size, a, b_t, InputLayout::Transposed, c).unwrap();
                }
            }
            ExecMode::Pipelined => {
                let mut pending: Vec<(usize, xdna_repro::coordinator::Ticket)> = Vec::new();
                for (i, (size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
                    if pending.len() == 2 {
                        let (j, t) = pending.remove(0);
                        eng.wait(t, &mut outs[j]).unwrap();
                    }
                    let t = eng
                        .submit(*size, a, InputLayout::RowMajor, b_t, InputLayout::Transposed)
                        .unwrap();
                    pending.push((i, t));
                }
                for (j, t) in pending {
                    eng.wait(t, &mut outs[j]).unwrap();
                }
            }
        }
    }
    eng
}

fn main() {
    // Modeled epoch-level schedule for the full 124M GEMM stream.
    pipeline::print(&PowerProfile::mains());
    pipeline::print(&PowerProfile::battery());

    // Measured engine runs over a trio of forward sizes.
    let sizes = [
        ProblemSize::new(256, 768, 768),
        ProblemSize::new(256, 768, 2304),
        ProblemSize::new(256, 2304, 768),
    ];
    println!(
        "\n=== Measured engine: serial vs pipelined over {} forward sizes ===",
        sizes.len()
    );
    for mode in [ExecMode::Serial, ExecMode::Pipelined] {
        let eng = run_stream(mode, &sizes, 5);
        println!("\n-- {mode:?} --");
        let total = eng.stages.total().as_secs_f64();
        for s in STAGES {
            let t = eng.stages.get(s).as_secs_f64();
            println!("{:<14} {:>10.3} ms ({:>5.1}%)", s, t * 1e3, 100.0 * t / total);
        }
        println!(
            "modeled: serial {:.3} ms, overlapped {:.3} ms, hidden {:.3} ms ({:.1}%)",
            eng.pipeline.serial_s() * 1e3,
            eng.pipeline.makespan_s() * 1e3,
            eng.pipeline.hidden_s() * 1e3,
            100.0 * eng.pipeline.hidden_s() / eng.pipeline.serial_s()
        );
    }
}
