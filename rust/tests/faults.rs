//! Fault-tolerance acceptance: seeded fault plans driven through the
//! whole stack must never change numerics or kill a session. Retryable
//! faults (transient, sync error, armed stuck kernel) and recovered
//! context losses leave training losses, GEMM outputs, and serve token
//! streams bit-identical to the fault-free baseline — on all twelve
//! GPT-2 site shapes, through both step executors — with a recovered
//! device resuming the frozen plan (no re-record). Fatal faults surface
//! cleanly and leave the session reusable; a quarantined session
//! degrades to the host-op oracle bit-identically and releases its
//! arbiter lease. See `docs/RELIABILITY.md`.

use xdna_repro::coordinator::executor::ExecutorMode;
use xdna_repro::coordinator::plan::{PlanCache, PlanOp, StepPlan};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
};
use xdna_repro::coordinator::{
    ColumnQuota, DeviceArbiter, FaultInjector, FaultKind, FaultPlan, RetryPolicy, SimulatorDevice,
};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::model::generate::{serve, GenRequest, Generation, ServeConfig};
use xdna_repro::model::kv_cache::KvCacheMode;
use xdna_repro::model::trainer::{train_synthetic, TrainBackend, TrainConfig};
use xdna_repro::model::{Gpt2Model, ModelConfig};
use xdna_repro::util::rng::Rng;

const DATA_SEED: u64 = 5;
const MODEL_SEED: u64 = 71;
const FAULT_SEED: u64 = 17;

/// A depth-2 unsharded session on an injector-wrapped simulator device.
fn faulty_session(plan: FaultPlan, retry: RetryPolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(2),
            shards: ShardPolicy::Fixed(Shards(1)),
            schedule: SchedulePolicy::BatchBySize,
            device: Box::new(FaultInjector::new(Box::new(SimulatorDevice), plan)),
            retry,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

fn clean_session() -> OffloadSession {
    faulty_session(FaultPlan::new(), RetryPolicy::default())
}

/// All twelve GPT-2 GEMM-site shapes at the reduced dimensions the other
/// integration suites use.
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Record the twelve-shape step on `sess`; returns the outputs (numerics
/// happen at record time — `execute` prices the schedule).
fn record_twelve_shapes(sess: &mut OffloadSession) -> Vec<Vec<f32>> {
    let sizes = scaled_gpt2_sizes();
    let mut plan = StepPlan::new();
    let mut outs = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let op = PlanOp::new(size)
            .with_b_layout(InputLayout::Transposed)
            .prefetchable_b(true);
        let mut c = vec![0.0f32; size.m * size.n];
        sess.record_gemm(&mut plan, &op, &a, &b_t, &mut c).unwrap();
        outs.push(c);
    }
    sess.execute(&mut plan).unwrap();
    outs
}

/// Every retryable fault kind — transient execution fault, BO sync
/// error, and a stuck kernel under an armed op deadline — re-runs the
/// invocation bit-identically on the twelve GPT-2 site shapes. A failed
/// run stages nothing, so the re-run reproduces the exact bf16 result.
#[test]
fn retryable_faults_bit_identical_on_all_gpt2_site_shapes() {
    let baseline = record_twelve_shapes(&mut clean_session());

    // Unsharded, so op i's first attempt is device run i plus earlier
    // retries; the indices below hit three distinct ops.
    let plan = FaultPlan::new()
        .at(0, FaultKind::Transient)
        .at(5, FaultKind::SyncError)
        .at(13, FaultKind::StuckKernel);
    let retry = RetryPolicy {
        op_deadline_s: Some(0.25), // arms stuck-kernel detection
        ..RetryPolicy::default()
    };
    let mut sess = faulty_session(plan, retry);
    let outs = record_twelve_shapes(&mut sess);
    assert_eq!(outs, baseline, "a retried invocation must be bit-identical");
    assert_eq!(sess.faults.seen, 3);
    assert_eq!(sess.faults.retried, 3);
    assert_eq!(sess.faults.recovered, 0);
    assert!(!sess.quarantined());
}

/// A context loss mid-step recovers — re-open, re-prepare the registry,
/// resume — without changing any output, and the session then records
/// further steps normally.
#[test]
fn device_loss_mid_step_recovers_bit_identically() {
    let baseline = record_twelve_shapes(&mut clean_session());
    let plan = FaultPlan::new().at(6, FaultKind::DeviceLost);
    let mut sess = faulty_session(plan, RetryPolicy::default());
    let outs = record_twelve_shapes(&mut sess);
    assert_eq!(outs, baseline, "a recovered device must be bit-identical");
    assert_eq!(sess.faults.seen, 1);
    assert_eq!(sess.faults.recovered, 1);
    assert_eq!(sess.faults.retried, 0, "recovery does not consume a retry");
    assert!(!sess.quarantined());
    // The recovered session keeps working: a fresh step, still identical.
    assert_eq!(record_twelve_shapes(&mut sess), baseline);
}

fn train_cfg() -> TrainConfig {
    TrainConfig {
        batch: 2,
        seq: 16,
        epochs: 2,
        steps_per_epoch: 2,
        ..Default::default()
    }
}

/// d2 training losses through the planned/cached path under a seeded
/// fault spec; returns (losses, session, cache counters).
fn train_with_faults(spec: &str, executor: ExecutorMode) -> (Vec<f32>, OffloadSession, (u64, u64)) {
    let plan = FaultPlan::parse(spec, FAULT_SEED).unwrap();
    let mut sess = faulty_session(plan, RetryPolicy::default());
    let mut cache = PlanCache::new();
    let stats = train_synthetic(
        ModelConfig::d2(),
        &train_cfg(),
        &mut TrainBackend::CpuNpuPlanned {
            session: &mut sess,
            cache: Some(&mut cache),
            executor,
        },
        DATA_SEED,
    )
    .unwrap();
    let losses = stats.iter().map(|e| e.loss).collect();
    let counters = (cache.hits(), cache.misses());
    (losses, sess, counters)
}

/// The training differential, through both step executors: a transient
/// storm and a recovered context loss each leave every epoch loss
/// bit-identical to the fault-free run — and the recovery resumes the
/// frozen plan, so the cache still records exactly once.
#[test]
fn training_losses_bit_identical_under_faults_on_both_executors() {
    for executor in [ExecutorMode::Sync, ExecutorMode::Background] {
        let (baseline, sess, (hits, misses)) = train_with_faults("", executor);
        assert_eq!(sess.faults.seen, 0);
        assert_eq!((hits, misses), (3, 1), "{executor:?}: 4 steps, 1 record");

        let (losses, sess, counters) = train_with_faults("transient:2,sync:1", executor);
        assert_eq!(losses, baseline, "{executor:?}: retries changed numerics");
        assert_eq!(sess.faults.seen, 3);
        assert_eq!(sess.faults.retried, 3);
        assert!(!sess.quarantined());
        assert_eq!(counters, (3, 1), "{executor:?}: retries must not re-record");

        let (losses, sess, counters) = train_with_faults("device-lost:1", executor);
        assert_eq!(losses, baseline, "{executor:?}: recovery changed numerics");
        assert_eq!(sess.faults.seen, 1);
        assert_eq!(sess.faults.recovered, 1);
        assert!(!sess.quarantined());
        assert_eq!(
            counters,
            (3, 1),
            "{executor:?}: recovery must resume the frozen plan, not re-record"
        );
    }
}

/// A permanent context loss quarantines the session and the trainer
/// degrades every remaining step to the host-op oracle — bit-identical
/// to the all-CPU backend — through the background executor too (the
/// sync path is pinned by `bench faults`' own tests).
#[test]
fn quarantined_training_matches_the_cpu_oracle_through_the_background_executor() {
    let oracle: Vec<f32> = train_synthetic(ModelConfig::d2(), &train_cfg(), &mut TrainBackend::Cpu, DATA_SEED)
        .unwrap()
        .iter()
        .map(|e| e.loss)
        .collect();
    let (losses, sess, _) = train_with_faults("quarantine", ExecutorMode::Background);
    assert!(sess.quarantined());
    assert_eq!(sess.faults.recovered, 0, "permanent loss: recovery fails");
    assert!(sess.faults.fallback_steps >= 1);
    assert!(sess.faults.fallback_ops > 0);
    assert_eq!(losses, oracle, "host fallback must match the CPU backend bit for bit");
}

/// An unarmed stuck kernel is fatal (there is no detection mechanism to
/// make re-running meaningful), but the error surfaces cleanly and the
/// session keeps working; arming the op deadline turns the same fault
/// into a retry.
#[test]
fn stuck_kernel_fatal_unarmed_retryable_armed() {
    let size = scaled_gpt2_sizes()[0];
    let (a, b_t) = random_inputs(size, 42);
    let record_one = |sess: &mut OffloadSession| -> xdna_repro::util::error::Result<Vec<f32>> {
        let mut plan = StepPlan::new();
        let op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
        let mut c = vec![0.0f32; size.m * size.n];
        sess.record_gemm(&mut plan, &op, &a, &b_t, &mut c)?;
        sess.execute(&mut plan)?;
        Ok(c)
    };
    let baseline = record_one(&mut clean_session()).unwrap();

    let plan = FaultPlan::new().at(0, FaultKind::StuckKernel);
    let mut sess = faulty_session(plan, RetryPolicy::default());
    let err = record_one(&mut sess).unwrap_err();
    assert!(err.is_timeout(), "{err}");
    assert_eq!(sess.faults.seen, 0, "a fatal class takes no fault counters");
    assert!(!sess.quarantined());
    // The session survives the surfaced fault (the fault index is spent).
    assert_eq!(record_one(&mut sess).unwrap(), baseline);

    let plan = FaultPlan::new().at(0, FaultKind::StuckKernel);
    let armed = RetryPolicy {
        op_deadline_s: Some(0.25),
        ..RetryPolicy::default()
    };
    let mut sess = faulty_session(plan, armed);
    assert_eq!(record_one(&mut sess).unwrap(), baseline);
    assert_eq!((sess.faults.seen, sess.faults.retried), (1, 1));
}

/// With retry disabled a transient fault surfaces as "retries exhausted"
/// — classified, counted, and *recoverable*: the next step on the same
/// session succeeds bit-identically.
#[test]
fn exhausted_retries_surface_cleanly_and_leave_the_session_usable() {
    let baseline = record_twelve_shapes(&mut clean_session());
    let plan = FaultPlan::new().at(0, FaultKind::Transient);
    let no_retry = RetryPolicy {
        max_retries: 0,
        ..RetryPolicy::default()
    };
    let mut sess = faulty_session(plan, no_retry);
    let size = scaled_gpt2_sizes()[0];
    let (a, b_t) = random_inputs(size, 4000);
    let mut plan_step = StepPlan::new();
    let op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
    let mut c = vec![0.0f32; size.m * size.n];
    let err = sess.record_gemm(&mut plan_step, &op, &a, &b_t, &mut c).unwrap_err();
    assert!(err.to_string().contains("retries exhausted"), "{err}");
    assert!(err.to_string().contains("injected transient"), "{err}");
    assert_eq!((sess.faults.seen, sess.faults.retried), (1, 0));
    assert!(!sess.quarantined());
    drop(plan_step);
    assert_eq!(record_twelve_shapes(&mut sess), baseline);
}

/// The eager path never re-runs an op (completed strips' modeled charges
/// would double-count): the fault surfaces at `wait()` — but the session
/// still counts it, recovers the lost context, and the very next eager
/// op succeeds bit-identically.
#[test]
fn eager_fault_surfaces_at_wait_and_context_loss_recovers() {
    let size = scaled_gpt2_sizes()[0];
    let (a, b_t) = random_inputs(size, 4000);
    let mut reference = vec![0.0f32; size.m * size.n];
    clean_session()
        .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
        .unwrap();

    let plan = FaultPlan::new().at(1, FaultKind::DeviceLost);
    let mut sess = faulty_session(plan, RetryPolicy::default());
    let mut c = vec![0.0f32; size.m * size.n];
    sess.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
    assert_eq!(c, reference);
    let err = sess.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap_err();
    assert!(err.to_string().contains("injected context loss"), "{err}");
    assert_eq!((sess.faults.seen, sess.faults.recovered), (1, 1));
    assert!(!sess.quarantined());
    let mut again = vec![0.0f32; size.m * size.n];
    sess.gemm(size, &a, &b_t, InputLayout::Transposed, &mut again).unwrap();
    assert_eq!(again, reference, "the recovered eager session must be bit-identical");
}

fn requests() -> Vec<GenRequest> {
    vec![
        GenRequest::new((0..4).map(|i| (i * 7 + 3) % 256).collect(), 6, 21),
        GenRequest::new((0..2).map(|i| (i * 7 + 11) % 256).collect(), 8, 22),
    ]
}

fn serve_once(sess: &mut OffloadSession, cache: &mut PlanCache) -> Vec<Generation> {
    let mut model = Gpt2Model::new(ModelConfig::d2(), MODEL_SEED);
    let cfg = ServeConfig {
        max_batch: 2,
        temperature: 1.0,
        kv_cache: KvCacheMode::On,
        ..Default::default()
    };
    serve(&mut model, &requests(), sess, Some(cache), &cfg)
        .unwrap()
        .generations
}

/// Serving under a transient storm plus a recovered context loss streams
/// bit-identical tokens and logits, and the same session serves a second
/// batch afterwards — recovery leaves it fully reusable.
#[test]
fn serve_under_recoverable_faults_bit_identical_and_reusable() {
    let baseline = serve_once(&mut clean_session(), &mut PlanCache::new());
    let plan = FaultPlan::parse("transient:2,device-lost:1", FAULT_SEED).unwrap();
    let mut sess = faulty_session(plan, RetryPolicy::default());
    let mut cache = PlanCache::new();
    let faulted = serve_once(&mut sess, &mut cache);
    assert_eq!(faulted.len(), baseline.len());
    for (f, b) in faulted.iter().zip(&baseline) {
        assert_eq!(f.tokens, b.tokens, "request {}: faults changed the stream", f.id);
        assert_eq!(f.final_logits, b.final_logits, "request {} logits", f.id);
        assert!(!f.expired);
    }
    assert_eq!(sess.faults.seen, 3);
    assert_eq!(sess.faults.retried, 2);
    assert_eq!(sess.faults.recovered, 1);
    assert!(!sess.quarantined());
    // All faults are spent: the same session serves the next batch too.
    let again = serve_once(&mut sess, &mut cache);
    for (f, b) in again.iter().zip(&baseline) {
        assert_eq!(f.tokens, b.tokens, "request {}: reuse changed the stream", f.id);
    }
}

/// A quarantined serving session keeps streaming on the host oracle:
/// every request completes its full budget, deterministically across
/// runs, with the fallback counters recording the degradation.
#[test]
fn quarantined_serve_keeps_streaming_deterministically() {
    let run = || {
        let plan = FaultPlan::parse("quarantine", FAULT_SEED).unwrap();
        let mut sess = faulty_session(plan, RetryPolicy::default());
        let gens = serve_once(&mut sess, &mut PlanCache::new());
        (gens, sess.faults.clone())
    };
    let (gens, faults) = run();
    assert!(faults.quarantined);
    assert_eq!(faults.recovered, 0);
    assert!(faults.fallback_steps >= 1);
    assert!(faults.fallback_ops > 0);
    for (g, r) in gens.iter().zip(&requests()) {
        assert_eq!(g.tokens.len(), r.max_new_tokens, "request {} must finish its budget", g.id);
        assert!(!g.final_logits.is_empty());
    }
    let (again, _) = run();
    for (a, b) in again.iter().zip(&gens) {
        assert_eq!(a.tokens, b.tokens, "host-oracle serving must be deterministic");
        assert_eq!(a.final_logits, b.final_logits);
    }
}

/// A quarantined tenant releases its lease: its dedicated columns go
/// back to the pool (a replacement tenant that could not attach before
/// can attach after), and the arbiter report records the quarantine.
#[test]
fn quarantine_releases_the_tenants_arbiter_lease() {
    let arbiter = DeviceArbiter::new();
    let two_col = |plan: FaultPlan| {
        OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(2),
                shards: ShardPolicy::Fixed(Shards(2)),
                schedule: SchedulePolicy::BatchBySize,
                device: Box::new(FaultInjector::new(Box::new(SimulatorDevice), plan)),
                ..Default::default()
            },
            &[],
        )
        .unwrap()
    };
    let mut chaos = two_col(FaultPlan::parse("quarantine", FAULT_SEED).unwrap());
    chaos.attach_arbiter(&arbiter, "chaos", ColumnQuota::Fixed(2)).unwrap();
    let mut steady = two_col(FaultPlan::new());
    steady.attach_arbiter(&arbiter, "steady", ColumnQuota::Fixed(2)).unwrap();
    // The 4-column array is fully leased: no room for a third tenant.
    let mut replacement = two_col(FaultPlan::new());
    assert!(replacement.attach_arbiter(&arbiter, "replacement", ColumnQuota::Fixed(2)).is_err());

    let losses: Vec<f32> = train_synthetic(
        ModelConfig::d2(),
        &train_cfg(),
        &mut TrainBackend::CpuNpuPlanned {
            session: &mut chaos,
            cache: None,
            executor: ExecutorMode::Sync,
        },
        DATA_SEED,
    )
    .unwrap()
    .iter()
    .map(|e| e.loss)
    .collect();
    assert!(chaos.quarantined());
    let oracle: Vec<f32> =
        train_synthetic(ModelConfig::d2(), &train_cfg(), &mut TrainBackend::Cpu, DATA_SEED)
            .unwrap()
            .iter()
            .map(|e| e.loss)
            .collect();
    assert_eq!(losses, oracle, "the quarantined tenant still trains, on the host oracle");

    assert!(chaos.tenant_report().unwrap().quarantined);
    let report = arbiter.report();
    assert_eq!(report.quarantined, 1);
    // The freed columns are leasable again.
    replacement.attach_arbiter(&arbiter, "replacement", ColumnQuota::Fixed(2)).unwrap();
    // And the healthy tenant was never disturbed.
    let steady_losses: Vec<f32> = train_synthetic(
        ModelConfig::d2(),
        &train_cfg(),
        &mut TrainBackend::CpuNpuPlanned {
            session: &mut steady,
            cache: None,
            executor: ExecutorMode::Sync,
        },
        DATA_SEED,
    )
    .unwrap()
    .iter()
    .map(|e| e.loss)
    .collect();
    assert!(!steady.quarantined());
    assert!(steady_losses.iter().all(|l| l.is_finite()));
}
