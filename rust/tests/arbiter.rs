//! Device-arbiter integration: a solo arbitrated session must behave
//! bit-for-bit like an unattached one (GEMM outputs, Figure-7 stage
//! breakdown, training losses, decode streams), quota and attachment
//! misuse must fail with specific errors, and a 4-way fixed-lease
//! split must keep every tenant inside its partition with near-perfect
//! fairness on identical workloads.

use xdna_repro::coordinator::executor::ExecutorMode;
use xdna_repro::coordinator::plan::PlanCache;
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
    STAGE_INPUT_COPY, STAGE_INPUT_SYNC, STAGE_KERNEL, STAGE_OUTPUT_COPY, STAGE_OUTPUT_SYNC,
    STAGE_RECONFIG, STAGE_TRANSPOSE,
};
use xdna_repro::coordinator::{ColumnQuota, DeviceArbiter};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::model::generate::{serve, GenRequest, ServeConfig};
use xdna_repro::model::trainer::{train_synthetic, TrainBackend, TrainConfig};
use xdna_repro::model::{Gpt2Model, ModelConfig};
use xdna_repro::power::profiles::PowerProfile;
use xdna_repro::util::rng::Rng;

const ALL_STAGES: [&str; 7] = [
    STAGE_INPUT_COPY,
    STAGE_TRANSPOSE,
    STAGE_INPUT_SYNC,
    STAGE_RECONFIG,
    STAGE_KERNEL,
    STAGE_OUTPUT_SYNC,
    STAGE_OUTPUT_COPY,
];

fn session(depth: usize, shards: usize, schedule: SchedulePolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards: ShardPolicy::Fixed(Shards(shards)),
            schedule,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

/// The twelve GPT-2 GEMM-site shapes at the reduced dimensions the other
/// integration suites use (same fwd / bwd-data / bwd-weight patterns,
/// shrunk to stay fast in CI).
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k];
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Holding a lease must never change numerics or the local schedule: on
/// every one of the twelve site shapes, an arbitrated session's output
/// and modeled makespan equal the unattached session's exactly.
#[test]
fn solo_arbitrated_gemm_bit_identical_on_all_twelve_site_shapes() {
    for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let mut plain_out = vec![0.0f32; size.m * size.n];
        let mut plain = session(2, 2, SchedulePolicy::BatchBySize);
        plain.gemm(size, &a, &b_t, InputLayout::Transposed, &mut plain_out).unwrap();

        let arbiter = DeviceArbiter::new();
        let mut leased = session(2, 2, SchedulePolicy::BatchBySize);
        leased.attach_arbiter(&arbiter, "solo", ColumnQuota::FairShare).unwrap();
        assert!(leased.arbitrated());
        let mut leased_out = vec![0.0f32; size.m * size.n];
        leased.gemm(size, &a, &b_t, InputLayout::Transposed, &mut leased_out).unwrap();

        assert_eq!(plain_out, leased_out, "{size}: lease changed numerics");
        assert_eq!(
            plain.pipeline.makespan_s(),
            leased.pipeline.makespan_s(),
            "{size}: lease changed the local schedule"
        );
        let t = leased.tenant_report().unwrap();
        assert!(t.windows >= 1 && t.ops >= 1, "{size}: window uncharged");
    }
}

/// A depth-1 FIFO session is the paper's strictly serial Figure-7
/// invocation path; attaching it to an arbiter must leave the per-stage
/// modeled breakdown identical, stage for stage.
#[test]
fn depth1_fifo_stage_breakdown_unchanged_by_attachment() {
    let sizes = scaled_gpt2_sizes();
    let run = |arbiter: Option<&DeviceArbiter>| -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut sess = session(1, 1, SchedulePolicy::Fifo);
        if let Some(arb) = arbiter {
            sess.attach_arbiter(arb, "fig7", ColumnQuota::FairShare).unwrap();
        }
        let mut outs = Vec::new();
        for (i, &size) in sizes.iter().enumerate() {
            let (a, b_t) = random_inputs(size, 5000 + i as u64);
            let mut c = vec![0.0f32; size.m * size.n];
            sess.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
            outs.push(c);
        }
        let stages = ALL_STAGES.iter().map(|s| sess.modeled_stage_s(s)).collect();
        (outs, stages)
    };
    let (plain_outs, plain_stages) = run(None);
    let arbiter = DeviceArbiter::new();
    let (leased_outs, leased_stages) = run(Some(&arbiter));
    assert_eq!(plain_outs, leased_outs, "attachment changed numerics");
    for (name, (p, l)) in ALL_STAGES.iter().zip(plain_stages.iter().zip(&leased_stages)) {
        assert_eq!(p, l, "stage '{name}' modeled seconds diverged under the lease");
    }
    assert!(arbiter.makespan_s() > 0.0, "the solo tenant's windows were never placed");
}

/// End to end on the model paths: a planned-and-cached training run and
/// a KV-cached decode stream produce bit-identical losses / tokens /
/// logits whether or not the session holds a lease.
#[test]
fn arbitrated_training_and_decode_match_unarbitrated() {
    let cfg = ModelConfig::d2();
    let tc = TrainConfig {
        batch: 2,
        seq: 16,
        epochs: 2,
        steps_per_epoch: 2,
        power: PowerProfile::mains(),
        ..Default::default()
    };
    let train_losses = |arbiter: Option<&DeviceArbiter>| -> Vec<f32> {
        let mut sess = session(2, 2, SchedulePolicy::BatchBySize);
        if let Some(arb) = arbiter {
            sess.attach_arbiter(arb, "train", ColumnQuota::Fixed(2)).unwrap();
        }
        let mut cache = PlanCache::new();
        let stats = train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess,
                cache: Some(&mut cache),
                executor: ExecutorMode::Sync,
            },
            17,
        )
        .unwrap();
        assert!(cache.hits() >= 1, "the cached step must replay");
        stats.iter().map(|s| s.loss).collect()
    };
    let arbiter = DeviceArbiter::new();
    assert_eq!(
        train_losses(None),
        train_losses(Some(&arbiter)),
        "training losses diverged under the lease"
    );

    let requests: Vec<GenRequest> = (0..3)
        .map(|i| {
            let prompt: Vec<i32> = (0..4).map(|t| (t * 11 + i) % 256).collect();
            GenRequest::new(prompt, 6, 900 + i as u64)
        })
        .collect();
    let decode = |arbiter: Option<&DeviceArbiter>| {
        let mut model = Gpt2Model::new(cfg, 71);
        let mut sess = session(2, 2, SchedulePolicy::BatchBySize);
        if let Some(arb) = arbiter {
            sess.attach_arbiter(arb, "serve", ColumnQuota::FairShare).unwrap();
        }
        let mut cache = PlanCache::new();
        serve(
            &mut model,
            &requests,
            &mut sess,
            Some(&mut cache),
            &ServeConfig {
                temperature: 1.0,
                ..Default::default()
            },
        )
        .unwrap()
        .generations
    };
    let plain = decode(None);
    let leased = decode(Some(&arbiter));
    for (p, l) in plain.iter().zip(&leased) {
        assert_eq!(p.tokens, l.tokens, "request {} tokens diverged", p.id);
        assert_eq!(p.final_logits, l.final_logits, "request {} logits diverged", p.id);
    }
}

/// Attachment misuse fails up front with specific, actionable errors.
#[test]
fn attach_misuse_errors_are_specific() {
    let arbiter = DeviceArbiter::new();

    // One lease per session.
    let mut sess = session(1, 1, SchedulePolicy::Fifo);
    sess.attach_arbiter(&arbiter, "first", ColumnQuota::FairShare).unwrap();
    let err = sess.attach_arbiter(&arbiter, "again", ColumnQuota::FairShare).unwrap_err();
    assert!(
        err.to_string().contains("already holds an arbiter lease"),
        "unexpected error: {err}"
    );

    // A session wider than its fixed lease.
    let arbiter = DeviceArbiter::new();
    let mut wide = session(1, 4, SchedulePolicy::Fifo);
    let err = wide.attach_arbiter(&arbiter, "wide", ColumnQuota::Fixed(2)).unwrap_err();
    assert!(err.to_string().contains("widen the quota"), "unexpected error: {err}");

    // Fixed leases that over-subscribe the four columns.
    let arbiter = DeviceArbiter::new();
    let mut a = session(1, 3, SchedulePolicy::Fifo);
    a.attach_arbiter(&arbiter, "a", ColumnQuota::Fixed(3)).unwrap();
    let mut b = session(1, 2, SchedulePolicy::Fifo);
    let err = b.attach_arbiter(&arbiter, "b", ColumnQuota::Fixed(2)).unwrap_err();
    assert!(err.to_string().contains("over-subscribes"), "unexpected error: {err}");

    // A fixed lease that would starve an existing full-width fair tenant.
    let arbiter = DeviceArbiter::new();
    let mut fair = session(1, 4, SchedulePolicy::Fifo);
    fair.attach_arbiter(&arbiter, "fair", ColumnQuota::FairShare).unwrap();
    let mut fixed = session(1, 1, SchedulePolicy::Fifo);
    let err = fixed.attach_arbiter(&arbiter, "fixed", ColumnQuota::Fixed(1)).unwrap_err();
    assert!(
        err.to_string().contains("a fair-share tenant needs"),
        "unexpected error: {err}"
    );

    // A fair tenant wider than the undedicated remainder.
    let arbiter = DeviceArbiter::new();
    let mut fixed = session(1, 2, SchedulePolicy::Fifo);
    fixed.attach_arbiter(&arbiter, "fixed", ColumnQuota::Fixed(2)).unwrap();
    let mut fair = session(1, 4, SchedulePolicy::Fifo);
    let err = fair.attach_arbiter(&arbiter, "fair", ColumnQuota::FairShare).unwrap_err();
    assert!(err.to_string().contains("not dedicated"), "unexpected error: {err}");

    // Quota strings parse like the CLI flag (and reject nonsense).
    assert_eq!("fair".parse::<ColumnQuota>().unwrap(), ColumnQuota::FairShare);
    assert_eq!("fixed:3".parse::<ColumnQuota>().unwrap(), ColumnQuota::Fixed(3));
    assert!("fixed:0".parse::<ColumnQuota>().is_err());
    assert!("fixed:5".parse::<ColumnQuota>().is_err());
    assert!("half".parse::<ColumnQuota>().is_err());
}

/// Four width-1 tenants with `fixed:1` leases running identical
/// workloads: every tenant keeps its one-column lease, the array is
/// fully partitioned, and the fairness index is near 1.
#[test]
fn four_way_fixed_leases_stay_within_quota_and_fair() {
    let arbiter = DeviceArbiter::new();
    let mut tenants: Vec<OffloadSession> = (0..4)
        .map(|t| {
            let mut s = session(1, 1, SchedulePolicy::Fifo);
            s.attach_arbiter(&arbiter, &format!("t{t}"), ColumnQuota::Fixed(1)).unwrap();
            s
        })
        .collect();
    let size = ProblemSize::new(64, 128, 128);
    let (a, b_t) = random_inputs(size, 6000);
    let mut reference: Option<Vec<f32>> = None;
    // Interleave rounds round-robin so windows from all tenants contend.
    for _round in 0..3 {
        for sess in tenants.iter_mut() {
            let mut c = vec![0.0f32; size.m * size.n];
            sess.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
            match &reference {
                Some(r) => assert_eq!(r, &c, "tenants must not perturb each other's numerics"),
                None => reference = Some(c),
            }
        }
    }
    let rep = arbiter.report();
    assert_eq!(rep.tenants.len(), 4);
    for t in &rep.tenants {
        assert_eq!(t.quota, ColumnQuota::Fixed(1), "{}", t.name);
        assert_eq!(t.lease_width, 1, "{}", t.name);
        assert_eq!(t.windows, 3, "{}: one window per round", t.name);
        assert!(t.busy_s > 0.0, "{}: no device time charged", t.name);
        assert!(
            t.busy_s <= rep.makespan_s + 1e-9,
            "{}: a width-1 lease cannot out-bill one column over the makespan",
            t.name
        );
    }
    // Identical workloads on identical leases: near-perfect fairness.
    assert!(rep.jain_index > 0.95, "jain {}", rep.jain_index);
    assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-9);
    let share_sum: f64 = rep.tenants.iter().map(|t| t.makespan_share).sum();
    assert!(
        (share_sum - rep.utilization).abs() < 1e-9,
        "tenant shares {share_sum} must partition utilization {}",
        rep.utilization
    );
}
