//! Background step-executor acceptance: background-vs-sync bit-identity
//! on all twelve GPT-2 site shapes, executor shutdown mid-step leaving
//! the session reusable, repeated-run determinism (thread timing must
//! never leak into numerics), and the wallclock sanity check — a cached
//! d2 background run is not slower than the synchronous replay, because
//! the deferred weight-gradient invocations really do overlap the
//! trainer's CPU ops.

use xdna_repro::coordinator::executor::{run_replay_step, ExecutorMode};
use xdna_repro::coordinator::plan::{PlanCache, PlanOp, StepPlan};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::model::trainer::{train_synthetic, TrainBackend, TrainConfig};
use xdna_repro::model::ModelConfig;
use xdna_repro::util::error::Error;
use xdna_repro::util::rng::Rng;

fn session(depth: usize) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards: ShardPolicy::Fixed(Shards(1)),
            schedule: SchedulePolicy::BatchBySize,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions (the
/// same forward / backward-data / backward-weight patterns as 124M).
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

fn shape_op(size: ProblemSize) -> PlanOp {
    PlanOp::new(size)
        .with_b_layout(InputLayout::Transposed)
        .prefetchable_b(true)
}

/// Record + execute + freeze the twelve-shape step, returning the primed
/// session and cache.
fn cached_twelve_shape_session() -> (OffloadSession, PlanCache) {
    let sizes = scaled_gpt2_sizes();
    let mut sess = session(4);
    let mut plan = StepPlan::new();
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 9000 + i as u64);
        let mut c = vec![0.0f32; size.m * size.n];
        sess.record_gemm(&mut plan, &shape_op(size), &a, &b_t, &mut c)
            .unwrap();
    }
    sess.execute(&mut plan).unwrap();
    let mut cache = PlanCache::new();
    cache.insert(sess.freeze(plan).unwrap());
    (sess, cache)
}

/// Replay the cached twelve-shape step synchronously; returns outputs.
fn sync_replay(sess: &mut OffloadSession, cache: &PlanCache) -> Vec<Vec<f32>> {
    let mut replay = sess.begin_replay(cache).expect("entry cached");
    let mut outs = Vec::new();
    for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
        let (a, b_t) = random_inputs(size, 9000 + i as u64);
        let mut c = vec![0.0f32; size.m * size.n];
        sess.replay_gemm(&mut replay, &shape_op(size), &a, &b_t, &mut c)
            .unwrap();
        outs.push(c);
    }
    sess.finish_replay(replay).unwrap();
    outs
}

/// Replay the cached twelve-shape step through the background executor;
/// returns outputs.
fn background_replay(sess: &mut OffloadSession, cache: &PlanCache) -> Vec<Vec<f32>> {
    let entry = cache.latest_for(sess.session_id()).expect("entry cached");
    let (outs, report) = run_replay_step(sess, entry, |client| {
        let mut outs = Vec::new();
        for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
            let (a, b_t) = random_inputs(size, 9000 + i as u64);
            let mut c = vec![0.0f32; size.m * size.n];
            let op = shape_op(size);
            // SAFETY: the handle is waited before a/b_t/c leave this
            // iteration's borrows; errors quiesce the executor first.
            let (node, h) = unsafe { client.submit(&op, &a, &b_t, &mut c)? };
            client.set_chain(node);
            client.wait(h)?;
            outs.push(c);
        }
        Ok(outs)
    })
    .unwrap();
    assert_eq!(report.stats.len(), 12);
    assert!(report.wall_gemm_s > 0.0);
    outs
}

/// The tentpole acceptance: the background executor produces bit-identical
/// outputs to the synchronous replay on all twelve GPT-2 site shapes.
#[test]
fn background_bit_identical_to_sync_on_all_gpt2_site_shapes() {
    let (mut sess, cache) = cached_twelve_shape_session();
    let outs_sync = sync_replay(&mut sess, &cache);
    let outs_bg = background_replay(&mut sess, &cache);
    assert_eq!(
        outs_bg, outs_sync,
        "background execution must be bit-identical to sync on every site shape"
    );
}

/// Thread-timing independence: eight consecutive background replays of
/// the same step produce bit-identical outputs every time (invocations
/// run in record order on one executor thread; scheduling jitter must
/// never reach numerics).
#[test]
fn background_replay_deterministic_across_eight_runs() {
    let (mut sess, cache) = cached_twelve_shape_session();
    let reference = background_replay(&mut sess, &cache);
    for run in 1..8 {
        let outs = background_replay(&mut sess, &cache);
        assert_eq!(outs, reference, "run {run} diverged from run 0");
    }
}

/// Executor shutdown mid-step (the trainer body errors with work in
/// flight) leaves the session fully reusable: sync replays, background
/// replays, and fresh records all still work.
#[test]
fn shutdown_mid_step_leaves_the_session_reusable() {
    let (mut sess, cache) = cached_twelve_shape_session();
    let sizes = scaled_gpt2_sizes();

    let entry = cache.latest_for(sess.session_id()).unwrap();
    let err = run_replay_step(&mut sess, entry, |client| {
        // Submit-and-wait a few ops, then die mid-step.
        for (i, &size) in sizes.iter().take(3).enumerate() {
            let (a, b_t) = random_inputs(size, 9000 + i as u64);
            let mut c = vec![0.0f32; size.m * size.n];
            let op = shape_op(size);
            // SAFETY: waited within this iteration.
            let (_, h) = unsafe { client.submit(&op, &a, &b_t, &mut c)? };
            client.wait(h)?;
        }
        Err::<(), _>(Error::runtime("simulated trainer failure"))
    })
    .unwrap_err();
    assert!(err.to_string().contains("simulated trainer failure"), "{err}");
    assert_eq!(sess.in_flight(), 0);

    // The session still replays the cached step — both ways — and still
    // records a fresh plan.
    let outs_sync = sync_replay(&mut sess, &cache);
    let outs_bg = background_replay(&mut sess, &cache);
    assert_eq!(outs_bg, outs_sync);
    let size = sizes[0];
    let (a, b_t) = random_inputs(size, 42);
    let mut c = vec![0.0f32; size.m * size.n];
    let mut plan = StepPlan::new();
    sess.record_gemm(&mut plan, &shape_op(size), &a, &b_t, &mut c)
        .unwrap();
    sess.execute(&mut plan).unwrap();
}

/// The wallclock acceptance on a cached d2 training run: background
/// execution is not slower than sync, because the deferred dW
/// invocations genuinely overlap the trainer's backward CPU ops. Both
/// runs are measured min-of-2 to damp scheduler noise, and a small
/// tolerance absorbs what remains; the overlap itself is asserted
/// directly through the measured blocked-vs-serialized split.
#[test]
fn background_cached_d2_run_not_slower_than_sync() {
    let cfg = ModelConfig::d2();
    let tc = TrainConfig {
        batch: 4,
        seq: 64,
        epochs: 1,
        steps_per_epoch: 6,
        ..Default::default()
    };
    let run = |mode: ExecutorMode| -> (f64, f64, f64, f32) {
        let mut sess = session(4);
        let mut cache = PlanCache::new();
        let t0 = std::time::Instant::now();
        let stats = train_synthetic(
            cfg,
            &tc,
            &mut TrainBackend::CpuNpuPlanned {
                session: &mut sess,
                cache: Some(&mut cache),
                executor: mode,
            },
            11,
        )
        .unwrap();
        assert_eq!((cache.hits(), cache.misses()), (5, 1));
        (
            t0.elapsed().as_secs_f64(),
            sess.wall_gemm_s,
            sess.wall_blocked_s,
            stats.last().unwrap().loss,
        )
    };
    let (mut sync_wall, mut bg_wall) = (f64::INFINITY, f64::INFINITY);
    let (mut bg_gemm, mut bg_blocked) = (0.0, 0.0);
    let (mut loss_sync, mut loss_bg) = (0.0f32, 0.0f32);
    for _ in 0..2 {
        let (w, _, _, l) = run(ExecutorMode::Sync);
        sync_wall = sync_wall.min(w);
        loss_sync = l;
        let (w, g, b, l) = run(ExecutorMode::Background);
        if w < bg_wall {
            bg_wall = w;
            bg_gemm = g;
            bg_blocked = b;
        }
        loss_bg = l;
    }
    assert_eq!(loss_sync, loss_bg, "wallclock must be the only difference");
    // The strict overlap claims need a core for each thread; on a
    // starved runner (or under heavy parallel-test load) the trainer
    // and device-stage threads serialize and the measured split is
    // meaningless, so gate the wallclock asserts on real parallelism —
    // the loss/counter/timeline invariants above always hold.
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if cores < 4 {
        eprintln!(
            "skipping strict wallclock asserts: only {cores} core(s) available \
             (background {bg_wall}s vs sync {sync_wall}s, blocked {bg_blocked}s of \
             {bg_gemm}s serialized)"
        );
        return;
    }
    // Staging + device wallclock was hidden for real: the trainer spent
    // strictly less time blocked than the serialized GEMM cost.
    assert!(
        bg_blocked < bg_gemm,
        "background replays must hide some GEMM wallclock: blocked {bg_blocked}s vs \
         serialized {bg_gemm}s"
    );
    // And end to end the background run is not slower than sync. The d2
    // step leaves milliseconds of dW work to hide per layer, far above
    // the per-op handoff cost; the tolerance only absorbs parallel-test
    // scheduler noise on loaded CI runners.
    assert!(
        bg_wall <= sync_wall * 1.10 + 0.010,
        "background cached run must not be slower than sync: background {bg_wall}s vs \
         sync {sync_wall}s"
    );
}
