//! Serving-engine integration: KV-cached decode bit-identity against the
//! full-window recompute baseline across prompt/decode-length
//! combinations, batched multi-request decode bit-identity against solo
//! runs (with a ×8 determinism repeat), plan-cache decode counters
//! (record once, replay tokens−1 times), mid-stream occupancy
//! changes as recoverable divergences — the decode mirror of the
//! training-path coverage in `rust/tests/plan.rs` — and the
//! per-request decode deadline: an expired request retires with a
//! partial stream that is a prefix of the unconstrained run.

use xdna_repro::coordinator::plan::PlanCache;
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{OffloadSession, QueueDepth, SessionConfig};
use xdna_repro::model::generate::{serve, GenRequest, Generation, ServeConfig};
use xdna_repro::model::kv_cache::KvCacheMode;
use xdna_repro::model::{Gpt2Model, ModelConfig};

const MODEL_SEED: u64 = 71;

fn model() -> Gpt2Model {
    Gpt2Model::new(ModelConfig::d2(), MODEL_SEED)
}

fn session() -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(2),
            schedule: SchedulePolicy::BatchBySize,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

fn prompt(len: usize, salt: i32) -> Vec<i32> {
    (0..len as i32).map(|i| (i * 7 + salt) % 256).collect()
}

/// Serve one configuration on a fresh model + session + plan cache.
fn run(requests: &[GenRequest], kv: KvCacheMode, max_batch: usize) -> Vec<Generation> {
    let mut model = model();
    let mut session = session();
    let mut cache = PlanCache::new();
    let cfg = ServeConfig {
        max_batch,
        temperature: 1.0,
        kv_cache: kv,
        ..Default::default()
    };
    let cache_ref = kv.enabled().then_some(&mut cache);
    serve(&mut model, requests, &mut session, cache_ref, &cfg)
        .unwrap()
        .generations
}

fn assert_same_generations(a: &[Generation], b: &[Generation], what: &str) {
    assert_eq!(a.len(), b.len());
    for (ga, gb) in a.iter().zip(b) {
        assert_eq!(ga.tokens, gb.tokens, "{what}: request {} token stream", ga.id);
        assert!(!ga.final_logits.is_empty(), "{what}: request {} probe empty", ga.id);
        // Bit-identity probe: the exact f32 logits row the final token
        // was sampled from.
        assert_eq!(
            ga.final_logits, gb.final_logits,
            "{what}: request {} final logits row",
            ga.id
        );
    }
}

/// KV-cached decode must be bit-identical to recomputing the full window
/// per token, across short/long prompts and decode lengths (including a
/// prompt of one token — no prefill at all).
#[test]
fn kv_decode_bit_identical_to_recompute_across_shapes() {
    for (p_len, new_tokens) in [(1usize, 6usize), (4, 8), (9, 12)] {
        let requests = [GenRequest::new(prompt(p_len, 3), new_tokens, 1234)];
        let kv = run(&requests, KvCacheMode::On, 1);
        let recompute = run(&requests, KvCacheMode::Off, 1);
        assert_eq!(kv[0].tokens.len(), new_tokens);
        assert_same_generations(
            &kv,
            &recompute,
            &format!("prompt {p_len} x {new_tokens} tokens"),
        );
    }
}

/// Batched multi-request decode must be bit-identical to serving each
/// request alone: per-request determinism under interleaving. Repeated
/// ×8 to catch any run-to-run nondeterminism in the batched path.
#[test]
fn batched_decode_bit_identical_to_solo_runs_x8() {
    let requests = [
        GenRequest::new(prompt(1, 5), 7, 21),
        GenRequest::new(prompt(4, 11), 10, 22),
        GenRequest::new(prompt(6, 2), 5, 23),
    ];
    // Each request served alone (batch window 1, its own session).
    let solo: Vec<Generation> = requests
        .iter()
        .map(|r| run(std::slice::from_ref(r), KvCacheMode::On, 1).remove(0))
        .collect();
    let first = run(&requests, KvCacheMode::On, 3);
    for (b, s) in first.iter().zip(&solo) {
        assert_eq!(b.tokens, s.tokens, "request {} batched vs solo tokens", b.id);
        assert_eq!(b.final_logits, s.final_logits, "request {} batched vs solo logits", b.id);
    }
    for repeat in 0..8 {
        let again = run(&requests, KvCacheMode::On, 3);
        assert_same_generations(&again, &first, &format!("repeat {repeat}"));
    }
}

/// A T-token decode stream records its plan exactly once and replays it
/// T−1 times: hits == tokens − 1.
#[test]
fn decode_stream_records_once_and_replays_thereafter() {
    let tokens = 9;
    let mut model = model();
    let mut session = session();
    let mut cache = PlanCache::new();
    let requests = [GenRequest::new(prompt(1, 9), tokens, 321)];
    let cfg = ServeConfig {
        max_batch: 1,
        temperature: 1.0,
        kv_cache: KvCacheMode::On,
        ..Default::default()
    };
    let report = serve(&mut model, &requests, &mut session, Some(&mut cache), &cfg).unwrap();
    assert_eq!(report.tokens, tokens);
    assert_eq!(report.steps, tokens, "one decode step per generated token");
    assert_eq!(report.plan_cache_misses, 1, "the decode plan records exactly once");
    assert_eq!(
        report.plan_cache_hits as usize,
        tokens - 1,
        "every step after the first replays"
    );
    assert_eq!((cache.hits() as usize, cache.misses() as usize), (tokens - 1, 1));
    assert_eq!(report.latencies_s.len(), tokens);
}

/// When a request retires mid-stream the batch occupancy drops and the
/// cached plan's GEMM shapes change: that must surface as a recoverable
/// divergence (a second record), never an error.
#[test]
fn occupancy_change_is_a_recoverable_rerecord() {
    let mut model = model();
    let mut session = session();
    let mut cache = PlanCache::new();
    let requests = [
        GenRequest::new(prompt(1, 4), 3, 31),
        GenRequest::new(prompt(1, 6), 6, 32),
    ];
    let cfg = ServeConfig {
        max_batch: 2,
        temperature: 1.0,
        kv_cache: KvCacheMode::On,
        ..Default::default()
    };
    let report = serve(&mut model, &requests, &mut session, Some(&mut cache), &cfg).unwrap();
    assert_eq!(report.tokens, 3 + 6);
    // 3 steps at occupancy 2, then 3 at occupancy 1.
    assert_eq!(report.steps, 6);
    assert_eq!(
        report.plan_cache_misses, 2,
        "one record per occupancy bucket (the drop re-records)"
    );
    assert_eq!(report.plan_cache_hits, 4, "all other steps replay");
    // The re-recorded stream is still bit-identical per request: serve
    // the same requests solo and compare.
    for (i, req) in requests.iter().enumerate() {
        let solo = run(std::slice::from_ref(req), KvCacheMode::On, 1).remove(0);
        assert_eq!(report.generations[i].tokens, solo.tokens, "request {i}");
        assert_eq!(report.generations[i].final_logits, solo.final_logits, "request {i}");
    }
}

/// A request that outruns its decode deadline retires with its partial
/// stream — a strict prefix of the unconstrained run, marked expired and
/// counted on the fault ledger — while its batchmate completes normally,
/// and the mid-run occupancy drop stays a recoverable re-record.
#[test]
fn request_deadline_retires_with_a_partial_prefix_stream() {
    let requests = [
        GenRequest::new(prompt(1, 4), 1, 41), // completes at the first step
        GenRequest::new(prompt(3, 6), 8, 42), // will hit the deadline
    ];
    let serve_with = |timeout: Option<f64>| {
        let mut model = model();
        let mut session = session();
        let mut cache = PlanCache::new();
        let cfg = ServeConfig {
            max_batch: 2,
            temperature: 1.0,
            kv_cache: KvCacheMode::On,
            request_timeout_s: timeout,
            ..Default::default()
        };
        serve(&mut model, &requests, &mut session, Some(&mut cache), &cfg).unwrap()
    };
    let baseline = serve_with(None);
    assert_eq!(baseline.expired_requests(), 0);
    assert_eq!(baseline.generations[1].tokens.len(), 8);

    // Pin the deadline on the modeled clock so the long request expires
    // at exactly its fifth token: both runs share one clock trajectory
    // up to the expiry (the deadline changes nothing before it fires),
    // so reconstruct the clock at tokens 4 and 5 from the baseline's
    // per-token latencies and aim between them.
    let d = &baseline.generations[1].latencies_s;
    let clock_5 = baseline.modeled_s - d[5..].iter().sum::<f64>();
    let clock_4 = clock_5 - d[4];
    let wait = baseline.admission_waits_s[1];
    let report = serve_with(Some((clock_4 + clock_5) / 2.0 - wait));

    let short = &report.generations[0];
    assert_eq!(short.tokens, baseline.generations[0].tokens);
    assert!(!short.expired, "a request that finishes its budget never expires");

    let long = &report.generations[1];
    assert!(long.expired);
    assert_eq!(long.tokens.len(), 5, "the deadline must land after exactly five tokens");
    assert_eq!(
        long.tokens[..],
        baseline.generations[1].tokens[..5],
        "the partial stream is a prefix of the unconstrained run"
    );
    assert!(!long.final_logits.is_empty(), "the probe row survives an expiry");
    assert_eq!(report.expired_requests(), 1);
    assert_eq!(report.faults.expired_requests, 1);
    // 1 step at occupancy 2, then 4 at occupancy 1: the drop re-recorded
    // recoverably and every step either replayed or recorded.
    assert_eq!(report.steps, 5);
    assert_eq!(report.plan_cache_misses, 2, "one record per occupancy bucket");
    assert_eq!(report.plan_cache_hits + report.plan_cache_misses, report.steps as u64);
}
