//! Device-profile registry acceptance: profiles change schedules, never
//! bits.
//!
//! The contract of the target/objective axes ([`DeviceProfile`],
//! [`Objective`]): the functional datapath always runs the paper's 4x4
//! kernel, so any (target, objective) session is bit-identical to the
//! seed configuration on every GPT-2 site shape; the xdna1 default is
//! stage-for-stage identical to pre-profile code; cached plans recorded
//! for one target are recoverable misses on another; and the energy
//! objective never spends more modeled Joules than the makespan objective
//! on the same step — strictly less on the paper's 124M step.

use xdna_repro::bench::energy::{run_cell, step_flops};
use xdna_repro::coordinator::plan::{PlanCache, PlanOp, StepPlan};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy,
};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::npu::profile::{DeviceProfile, Objective};
use xdna_repro::power::profiles::PowerProfile;
use xdna_repro::util::rng::Rng;

fn session_for(
    profile: DeviceProfile,
    objective: Objective,
    depth: usize,
    shards: ShardPolicy,
    schedule: SchedulePolicy,
) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards,
            schedule,
            profile,
            objective,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions (same
/// forward / backward-data / backward-weight patterns as the 124M model,
/// shrunk so the functional datapath stays fast in CI). The full-scale
/// twelve are covered by the `--ignored` test below.
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Every (target, objective) cell must produce bit-identical outputs to
/// the seed configuration (xdna1, makespan, depth-1 FIFO), per shape.
fn bit_identical_across_targets(sizes: &[ProblemSize]) {
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        session_for(
            DeviceProfile::xdna1(),
            Objective::Makespan,
            1,
            ShardPolicy::Auto,
            SchedulePolicy::Fifo,
        )
        .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
        .unwrap();
        for profile in DeviceProfile::all() {
            for objective in [Objective::Makespan, Objective::EnergyEff] {
                let mut c = vec![0.0f32; size.m * size.n];
                session_for(
                    profile.clone(),
                    objective,
                    4,
                    ShardPolicy::Auto,
                    SchedulePolicy::BatchBySize,
                )
                .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c)
                .unwrap();
                assert_eq!(
                    reference,
                    c,
                    "{size}: target {} / objective {} must be bit-identical",
                    profile.name(),
                    objective
                );
            }
        }
    }
}

/// Bit-identity on all twelve GPT-2 site shapes across every registry
/// target and both objectives.
#[test]
fn targets_and_objectives_are_bit_identical_on_all_gpt2_site_shapes() {
    bit_identical_across_targets(&scaled_gpt2_sizes());
}

/// The same check at the paper's actual 124M problem sizes. Heavy (the
/// vocab-sized GEMMs are ~20 GFLOP each); run with
/// `cargo test --release -- --ignored`.
#[test]
#[ignore = "full-scale GPT-2 124M sizes; run with --release -- --ignored"]
fn targets_and_objectives_are_bit_identical_on_full_gpt2_sizes() {
    bit_identical_across_targets(&distinct_sizes(&ModelDims::gpt2_124m()));
}

/// An explicitly-configured xdna1/makespan session is *stage-for-stage*
/// identical to a `Default` session on the seed's depth-1 FIFO schedule:
/// same outputs, same modeled stage ledger, same timeline.
#[test]
fn explicit_xdna1_profile_is_stage_identical_to_the_default() {
    let mut default_sess = OffloadSession::new(SessionConfig::default(), &[]).unwrap();
    let mut profiled = session_for(
        DeviceProfile::xdna1(),
        Objective::Makespan,
        1,
        ShardPolicy::default(),
        SchedulePolicy::Fifo,
    );
    for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
        let (a, b_t) = random_inputs(size, 5000 + i as u64);
        let mut c_default = vec![0.0f32; size.m * size.n];
        let mut c_profiled = vec![0.0f32; size.m * size.n];
        default_sess
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c_default)
            .unwrap();
        profiled
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c_profiled)
            .unwrap();
        assert_eq!(c_default, c_profiled, "{size}: outputs diverged");
    }
    assert_eq!(
        default_sess.modeled_stages, profiled.modeled_stages,
        "per-stage modeled ledger must match stage for stage"
    );
    assert_eq!(
        default_sess.pipeline.makespan_s(),
        profiled.pipeline.makespan_s(),
        "identical schedules must cost identically"
    );
    assert_eq!(default_sess.pipeline.serial_s(), profiled.pipeline.serial_s());
    assert_eq!(default_sess.modeled_energy_j, profiled.modeled_energy_j);
}

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("xdna-profile-cache-{tag}-{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Record a small dry-run step and freeze it into a cache entry.
fn frozen_dry_step(sess: &mut OffloadSession) -> PlanCache {
    let mut plan = StepPlan::new();
    for size in [ProblemSize::new(64, 64, 128), ProblemSize::new(128, 64, 128)] {
        sess.record_modeled(&mut plan, &PlanOp::new(size).prefetchable_b(true))
            .unwrap();
    }
    sess.execute(&mut plan).unwrap();
    let mut cache = PlanCache::new();
    cache.insert(sess.freeze(plan).unwrap());
    cache
}

/// A plan-cache file written for one target must be a *recoverable miss*
/// — zero entries adopted, no error — for any other target or objective,
/// while the identical configuration round-trips.
#[test]
fn plan_cache_misses_recoverably_across_targets_and_objectives() {
    let path = tmp_path("cross-target");
    let mk_session = |profile: DeviceProfile, objective: Objective| {
        session_for(
            profile,
            objective,
            2,
            ShardPolicy::Auto,
            SchedulePolicy::BatchBySize,
        )
    };

    let mut s1 = mk_session(DeviceProfile::xdna1(), Objective::Makespan);
    let cache = frozen_dry_step(&mut s1);
    assert_eq!(
        cache.save_to(&path, s1.config_fingerprint(), s1.session_id()).unwrap(),
        1
    );

    // Same configuration, restarted process: the file adopts.
    let same = mk_session(DeviceProfile::xdna1(), Objective::Makespan);
    let mut loaded = PlanCache::new();
    assert_eq!(
        loaded.load_from(&path, same.config_fingerprint(), same.session_id()),
        1,
        "identical config must round-trip"
    );

    // Another target: different fingerprint, recoverable miss.
    let other_target = mk_session(DeviceProfile::xdna2(), Objective::Makespan);
    assert_ne!(
        s1.config_fingerprint(),
        other_target.config_fingerprint(),
        "the target must be part of the fingerprint"
    );
    let mut missed = PlanCache::new();
    assert_eq!(
        missed.load_from(&path, other_target.config_fingerprint(), other_target.session_id()),
        0,
        "a cross-target file is a recoverable miss, never an adoption"
    );
    assert_eq!(missed.len(), 0);

    // Another objective: also fingerprinted, also a clean miss.
    let other_objective = mk_session(DeviceProfile::xdna1(), Objective::EnergyEff);
    assert_ne!(
        s1.config_fingerprint(),
        other_objective.config_fingerprint(),
        "the objective must be part of the fingerprint"
    );
    let mut missed2 = PlanCache::new();
    assert_eq!(
        missed2.load_from(
            &path,
            other_objective.config_fingerprint(),
            other_objective.session_id()
        ),
        0
    );

    let _ = std::fs::remove_file(&path);
}

/// The acceptance bar on the paper's 124M step, on battery: the energy
/// objective never spends more modeled NPU Joules than the makespan
/// objective, and on xdna1 — where makespan-Auto shards the large sites
/// and pays their per-strip overhead energy — it spends strictly less,
/// so FLOPS/Ws strictly improves.
#[test]
fn energy_objective_beats_makespan_on_modeled_joules_for_the_124m_step() {
    let battery = PowerProfile::battery();
    assert!(step_flops() > 1e11, "the 124M step is hundreds of GFLOPs");
    for profile in DeviceProfile::all() {
        let name = profile.name();
        let mk = run_cell(profile.clone(), &battery, Objective::Makespan);
        let en = run_cell(profile, &battery, Objective::EnergyEff);
        assert!(
            en.energy_j <= mk.energy_j + 1e-9,
            "{name}: energy objective spent more: {en:?} vs {mk:?}"
        );
        if name == "xdna1" {
            assert!(
                en.energy_j < mk.energy_j,
                "{name}: strict improvement expected: {en:?} vs {mk:?}"
            );
            assert!(
                en.flops_per_ws > mk.flops_per_ws,
                "{name}: FLOPS/Ws must strictly improve on battery: {} vs {}",
                en.flops_per_ws,
                mk.flops_per_ws
            );
        }
    }
}
