//! Block-level offload integration: the whole transformer block — the
//! non-GEMM sites (layernorm, softmax) and the fused GELU epilogue —
//! recorded into the step plan with device-resident activation edges,
//! pinned by a differential harness against the host-op baseline.
//!
//! The contract under test: residency is a *modeling* property of the
//! plan. The physical numerics always run the host-op baseline, so a
//! block-offloaded step must be bit-identical — sampled token, logits,
//! probabilities, loss, and every gradient — to the GEMM-only eager
//! serial step, on all twelve GPT-2 site shapes, forward and backward,
//! across every rung (eager / planned / cached replay / background
//! replay). What the block offload *is allowed* to change is the modeled
//! schedule, and at d2 it must: the resident chain eliminates per-layer
//! host round-trips, so the depth-1 block-offloaded step strictly beats
//! the GEMM-only planned step's makespan.

use xdna_repro::coordinator::executor;
use xdna_repro::coordinator::plan::{
    FusedEpilogue, PlanCache, PlanOp, PlanOpKind, StepPlan, StepReport,
};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    InputLayout, OffloadSession, PrefetchHorizon, QueueDepth, SessionConfig, ShardPolicy, Shards,
};
use xdna_repro::gemm::sizes::{distinct_sizes, gemm_sites, ModelDims, Pass, ProblemSize};
use xdna_repro::model::ops::matmul::MatmulDispatch;
use xdna_repro::model::{Gpt2Model, ModelConfig};
use xdna_repro::util::rng::Rng;

fn session(depth: usize, shards: ShardPolicy, schedule: SchedulePolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards,
            schedule,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

fn fixed(n: usize) -> ShardPolicy {
    ShardPolicy::Fixed(Shards(n))
}

/// Everything a training step produces that the differential harness
/// compares bit-for-bit: the loss, a greedy-ish sampled next token, the
/// raw logits, the post-softmax probabilities, and the full gradient
/// arena.
struct StepOutcome {
    loss: f32,
    token: usize,
    logits: Vec<f32>,
    probs: Vec<f32>,
    grads: Vec<f32>,
}

fn outcome(model: &Gpt2Model, loss: f32) -> StepOutcome {
    let acts = model.acts.as_ref().expect("step ran");
    StepOutcome {
        loss,
        // Fixed RNG: bit-identical probs ⇒ bit-identical token.
        token: model.sample_next(&mut Rng::new(7), 0.8),
        logits: acts.logits.clone(),
        probs: acts.probs.clone(),
        grads: model.grads.as_slice().to_vec(),
    }
}

fn assert_bit_identical(got: &StepOutcome, want: &StepOutcome, rung: &str) {
    assert_eq!(got.loss, want.loss, "{rung}: loss must be bit-identical");
    assert_eq!(got.token, want.token, "{rung}: sampled token must match");
    assert_eq!(got.logits, want.logits, "{rung}: logits must be bit-identical");
    assert_eq!(got.probs, want.probs, "{rung}: probs must be bit-identical");
    assert_eq!(got.grads, want.grads, "{rung}: gradients must be bit-identical");
}

/// One planned (record) step: forward + backward through the `Plan`
/// dispatch, then `execute`. Returns the outcome and the step report.
fn planned_step(
    model: &mut Gpt2Model,
    sess: &mut OffloadSession,
    tokens: &[i32],
    targets: &[i32],
    b: usize,
    t: usize,
) -> (StepOutcome, StepPlan, StepReport) {
    let mut plan = StepPlan::new();
    let loss = {
        let mut d = MatmulDispatch::Plan {
            session: &mut *sess,
            plan: &mut plan,
        };
        let l = model
            .forward(&mut d, tokens, Some(targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut d).unwrap();
        l
    };
    let report = sess.execute(&mut plan).unwrap();
    assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
    (outcome(model, loss), plan, report)
}

/// The host-op baseline: GEMM-only eager offload through the paper's
/// strictly serial depth-1 session; every non-GEMM op is a host op.
fn baseline_step(
    cfg: ModelConfig,
    seed: u64,
    tokens: &[i32],
    targets: &[i32],
    b: usize,
    t: usize,
) -> StepOutcome {
    let mut model = Gpt2Model::new(cfg, seed);
    let mut sess = session(1, fixed(1), SchedulePolicy::Fifo);
    let loss = model
        .forward(&mut MatmulDispatch::Npu(&mut sess), tokens, Some(targets), b, t)
        .unwrap()
        .unwrap();
    model.zero_grad();
    model.backward(&mut MatmulDispatch::Npu(&mut sess)).unwrap();
    outcome(&model, loss)
}

/// The tentpole differential: a model whose GEMM stream covers all
/// twelve GPT-2 site shapes, stepped with block offload on through every
/// rung — eager, planned, cached synchronous replay, and background
/// replay — must produce the host-op baseline bit-for-bit: token,
/// logits, probs, loss, and gradients, forward and backward.
#[test]
fn block_offload_bit_identical_to_host_op_baseline_across_all_rungs() {
    // The scaled twelve-shape model (same site patterns as 124M).
    let cfg = ModelConfig {
        max_seq_len: 64,
        vocab_size: 1000,
        padded_vocab_size: 1024,
        num_layers: 2,
        num_heads: 2,
        channels: 128,
    };
    let (b, t) = (1usize, 64usize);
    let dims = ModelDims {
        batch: b,
        seq: t,
        channels: cfg.channels,
        padded_vocab: cfg.padded_vocab_size,
        layers: cfg.num_layers,
    };
    assert_eq!(distinct_sizes(&dims).len(), 12, "must cover all twelve site shapes");

    let mut rng = Rng::new(411);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let base = baseline_step(cfg, 2024, &tokens, &targets, b, t);

    // Rung 1 — eager: the flag is a plan-path property, so an eager step
    // with it set is *exactly* the baseline path.
    {
        let mut model = Gpt2Model::new(cfg, 2024);
        model.block_offload = true;
        let mut sess = session(1, fixed(1), SchedulePolicy::Fifo);
        let loss = model
            .forward(&mut MatmulDispatch::Npu(&mut sess), &tokens, Some(&targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut MatmulDispatch::Npu(&mut sess)).unwrap();
        assert_bit_identical(&outcome(&model, loss), &base, "eager");
    }

    // Rung 2 — planned: record the mixed-kind step and execute it whole.
    {
        let mut model = Gpt2Model::new(cfg, 2024);
        model.block_offload = true;
        let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
        let (out, plan, report) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
        assert_bit_identical(&out, &base, "planned");
        // 27 GEMMs + per-layer (ln1, ln2) + lnf + softmax.
        assert_eq!(plan.len(), 33, "every elementwise site must be recorded");
        assert_eq!(report.resident_edges, 8, "qkv/fc/fcproj per layer + lm-head + softmax");
        assert_eq!(report.elementwise_ops, 8, "6 elementwise sites + 2 fused GELU");
    }

    // Rung 3 — cached synchronous replay: freeze the recorded step, then
    // run the next step against the frozen schedule.
    {
        let mut model = Gpt2Model::new(cfg, 2024);
        model.block_offload = true;
        let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
        let mut cache = PlanCache::new();
        let (_, plan, _) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
        cache.insert(sess.freeze(plan).unwrap());

        let mut replay = sess.begin_replay(&cache).expect("mixed-kind step cached");
        let loss = {
            let mut d = MatmulDispatch::Replay {
                session: &mut sess,
                replay: &mut replay,
            };
            let l = model
                .forward(&mut d, &tokens, Some(&targets), b, t)
                .unwrap()
                .unwrap();
            model.zero_grad();
            model.backward(&mut d).unwrap();
            l
        };
        let report = sess.finish_replay(replay).unwrap();
        assert_bit_identical(&outcome(&model, loss), &base, "cached replay");
        assert_eq!(report.stats.len(), 33, "the frozen mixed-kind step replays whole");
        assert!(report.resident_edges > 0 && report.elementwise_ops > 0);
    }

    // Rung 4 — background replay: the same frozen step with the
    // device-stage loop on the executor thread and dW deferred.
    {
        let mut model = Gpt2Model::new(cfg, 2024);
        model.block_offload = true;
        let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
        let mut cache = PlanCache::new();
        let (_, plan, _) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
        cache.insert(sess.freeze(plan).unwrap());

        let entry = cache.latest_for(sess.session_id()).expect("cached");
        let (loss, report) = executor::run_replay_step(&mut sess, entry, |client| {
            let mut d = MatmulDispatch::BackgroundReplay { client };
            let l = model
                .forward(&mut d, &tokens, Some(&targets), b, t)?
                .unwrap();
            model.zero_grad();
            model.backward(&mut d)?;
            let MatmulDispatch::BackgroundReplay { client } = d else {
                unreachable!("dispatch fixed above")
            };
            client.drain_and_apply(model.grads.as_mut_slice())?;
            Ok(l)
        })
        .unwrap();
        assert_bit_identical(&outcome(&model, loss), &base, "background replay");
        assert!(report.resident_edges > 0 && report.elementwise_ops > 0);
    }
}

/// The acceptance schedule win, where it is structural: at depth 1 the
/// modeled makespan *is* the serial stage sum, so eliminating per-layer
/// host round-trips (resident A staging, A-input syncs, per-op dispatch
/// doorbells) must make the d2 block-offloaded step strictly faster than
/// the GEMM-only planned step — while the GEMM-only depth-1 plan keeps
/// the paper's Figure-7 strictly serial schedule, and numerics stay
/// bit-identical between the two.
#[test]
fn d2_block_offload_strictly_beats_gemm_only_planned_makespan() {
    let cfg = ModelConfig::d2();
    let (b, t) = (2usize, 16usize);
    let mut rng = Rng::new(83);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    let run = |block: bool| -> (StepOutcome, StepReport, f64, f64) {
        let mut model = Gpt2Model::new(cfg, 321);
        model.block_offload = block;
        let mut sess = session(1, fixed(1), SchedulePolicy::Fifo);
        let (out, _, report) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
        (out, report, sess.pipeline.makespan_s(), sess.pipeline.serial_s())
    };
    let (out_off, rep_off, m_off, s_off) = run(false);
    let (out_on, rep_on, m_on, s_on) = run(true);

    // GEMM-only depth-1: the Figure-7 strictly serial schedule, stage
    // for stage — record order, no overlap, no elementwise ops.
    assert_eq!(rep_off.order, (0..27).collect::<Vec<_>>());
    assert!((m_off - s_off).abs() < 1e-12, "depth 1 is strictly serial");
    assert_eq!((rep_off.resident_edges, rep_off.elementwise_ops), (0, 0));

    // Block offload: same bits, strictly less modeled time.
    assert_bit_identical(&out_on, &out_off, "block offload");
    assert!((m_on - s_on).abs() < 1e-12, "depth 1 stays strictly serial");
    assert_eq!((rep_on.resident_edges, rep_on.elementwise_ops), (8, 8));
    assert!(
        m_on < m_off,
        "the resident block chain must strictly beat the GEMM-only d2 \
         makespan: block {m_on} vs gemm-only {m_off}"
    );
}

/// A tiny deterministic LCG (no new deps) driving the randomized shape
/// sweep: ~50 (B, T, C) configurations, each stepped with block offload
/// on through one of the four rungs and compared bit-for-bit against the
/// host-op baseline.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> usize {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (self.0 >> 33) as usize
    }

    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.next() % xs.len()]
    }
}

#[test]
fn seeded_shape_fuzzer_block_offload_bit_identical_on_every_rung() {
    let mut lcg = Lcg(0x2545_F491_4F6C_DD1D);
    for i in 0..50usize {
        let channels = lcg.pick(&[16usize, 32, 64]);
        let cfg = ModelConfig {
            max_seq_len: 32,
            vocab_size: lcg.pick(&[32usize, 48, 64]),
            padded_vocab_size: 64,
            num_layers: lcg.pick(&[1usize, 2]),
            num_heads: lcg.pick(&[1usize, 2, 4]),
            channels,
        };
        let b = lcg.pick(&[1usize, 2]);
        let t = lcg.pick(&[8usize, 16, 24]);
        let mut rng = Rng::new(9000 + i as u64);
        let tokens: Vec<i32> =
            (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let targets: Vec<i32> =
            (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let ctx = format!(
            "config {i}: B={b} T={t} C={channels} L={} NH={} V={}",
            cfg.num_layers, cfg.num_heads, cfg.vocab_size
        );

        let base = baseline_step(cfg, 100 + i as u64, &tokens, &targets, b, t);
        let mut model = Gpt2Model::new(cfg, 100 + i as u64);
        model.block_offload = true;
        // Residency symmetry at every scale: qkv/fc/fcproj per layer +
        // lm-head + softmax edges; (2 ln per layer + lnf + softmax)
        // elementwise sites + one fused GELU per layer.
        let expect = 3 * cfg.num_layers + 2;

        let out = match i % 4 {
            // Planned, strictly serial.
            0 => {
                let mut sess = session(1, fixed(1), SchedulePolicy::Fifo);
                let (out, _, rep) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
                assert_eq!((rep.resident_edges, rep.elementwise_ops), (expect, expect), "{ctx}");
                out
            }
            // Planned, deep ring + whole-step batching.
            1 => {
                let mut sess = session(4, fixed(1), SchedulePolicy::BatchBySize);
                let (out, _, rep) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
                assert_eq!((rep.resident_edges, rep.elementwise_ops), (expect, expect), "{ctx}");
                out
            }
            // Cached synchronous replay of the frozen mixed-kind step.
            2 => {
                let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
                let mut cache = PlanCache::new();
                let (_, plan, _) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
                cache.insert(sess.freeze(plan).unwrap());
                let mut replay = sess.begin_replay(&cache).expect("cached");
                let loss = {
                    let mut d = MatmulDispatch::Replay {
                        session: &mut sess,
                        replay: &mut replay,
                    };
                    let l = model
                        .forward(&mut d, &tokens, Some(&targets), b, t)
                        .unwrap()
                        .unwrap();
                    model.zero_grad();
                    model.backward(&mut d).unwrap();
                    l
                };
                sess.finish_replay(replay).unwrap();
                outcome(&model, loss)
            }
            // Background replay with deferred dW.
            _ => {
                let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
                let mut cache = PlanCache::new();
                let (_, plan, _) = planned_step(&mut model, &mut sess, &tokens, &targets, b, t);
                cache.insert(sess.freeze(plan).unwrap());
                let entry = cache.latest_for(sess.session_id()).expect("cached");
                let (loss, _) = executor::run_replay_step(&mut sess, entry, |client| {
                    let mut d = MatmulDispatch::BackgroundReplay { client };
                    let l = model
                        .forward(&mut d, &tokens, Some(&targets), b, t)?
                        .unwrap();
                    model.zero_grad();
                    model.backward(&mut d)?;
                    let MatmulDispatch::BackgroundReplay { client } = d else {
                        unreachable!("dispatch fixed above")
                    };
                    client.drain_and_apply(model.grads.as_mut_slice())?;
                    Ok(l)
                })
                .unwrap();
                outcome(&model, loss)
            }
        };
        assert_bit_identical(&out, &base, &ctx);
    }
}

/// Record one op on the step's activation chain (modeled, no buffers).
fn chain_modeled(sess: &mut OffloadSession, plan: &mut StepPlan, op: PlanOp) {
    let mut op = op;
    if let Some(h) = plan.chain_head() {
        op = op.after(h);
    }
    let n = sess.record_modeled(plan, &op).unwrap();
    plan.set_chain(n);
}

/// The GPT-2 124M training step as a *modeled* block-offloaded plan —
/// the trainer's exact record pattern (per layer ln1 → qkv → attproj →
/// ln2 → fc(+fused GELU) → fcproj, then lnf → lm-head → softmax, then
/// the backward (dinp, dW) pairs in reverse), priced without allocating
/// the 124M buffers.
fn record_modeled_124m_block_step(sess: &mut OffloadSession) -> StepPlan {
    let dims = ModelDims::gpt2_124m();
    let (bt, c, vp) = (dims.bt(), dims.channels, dims.padded_vocab);
    let sites = gemm_sites(&dims);
    let fwd: Vec<_> = sites.iter().filter(|s| s.pass == Pass::Forward).collect();
    let layers = fwd[0].count;
    let size_of = |name: &str| fwd.iter().find(|s| s.op == name).unwrap().size;
    let gemm = |name: &str, resident: bool, fused: FusedEpilogue| {
        PlanOp::new(size_of(name))
            .with_b_layout(InputLayout::Transposed)
            .prefetchable_b(true)
            .with_fused(fused)
            .resident_input(resident)
    };
    let ln = || PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(bt, 1, c));

    let mut plan = StepPlan::new();
    for _ in 0..layers {
        chain_modeled(sess, &mut plan, ln());
        chain_modeled(sess, &mut plan, gemm("qkv", true, FusedEpilogue::None));
        // Attention runs on the host: attproj's input round-trips.
        chain_modeled(sess, &mut plan, gemm("attproj", false, FusedEpilogue::None));
        chain_modeled(sess, &mut plan, ln());
        chain_modeled(sess, &mut plan, gemm("fc", true, FusedEpilogue::Gelu));
        chain_modeled(sess, &mut plan, gemm("fcproj", true, FusedEpilogue::None));
    }
    chain_modeled(sess, &mut plan, ln());
    chain_modeled(sess, &mut plan, gemm("lm_head", true, FusedEpilogue::None));
    chain_modeled(
        sess,
        &mut plan,
        PlanOp::elementwise(PlanOpKind::Softmax, ProblemSize::new(bt, 1, vp)).resident_input(true),
    );

    // Backward: (dinp, dW) pairs, lm head first then layers in reverse —
    // GEMM-only, exactly the trainer's record order.
    let bwd_data: Vec<_> = sites.iter().filter(|s| s.pass == Pass::BackwardData).collect();
    let bwd_w: Vec<_> = sites.iter().filter(|s| s.pass == Pass::BackwardWeight).collect();
    let mut pair = |plan: &mut StepPlan, sess: &mut OffloadSession, name: &str| {
        let dinp = bwd_data.iter().find(|s| s.op == name).unwrap().size;
        let dw = bwd_w.iter().find(|s| s.op == name).unwrap().size;
        let head = plan.chain_head();
        let mut op_dinp = PlanOp::new(dinp).prefetchable_b(true);
        let mut op_dw = PlanOp::new(dw)
            .with_a_layout(InputLayout::Transposed)
            .prefetchable_b(true);
        if let Some(h) = head {
            op_dinp = op_dinp.after(h);
            op_dw = op_dw.after(h);
        }
        let n = sess.record_modeled(plan, &op_dinp).unwrap();
        sess.record_modeled(plan, &op_dw).unwrap();
        plan.set_chain(n);
    };
    pair(&mut plan, sess, "lm_head");
    for _ in 0..layers {
        for name in ["fcproj", "fc", "attproj", "qkv"] {
            pair(&mut plan, sess, name);
        }
    }
    plan
}

/// The capped per-step prefetch sweep on the block-level 124M step: a
/// mixed-kind plan at a deep ring prices every non-GEMM op
/// (`record_modeled`), the deep horizon's candidate sweep is capped, and
/// because `PrefetchHorizon::Next` is always in the capped candidate
/// set, the capped pick is never worse than the one-op hoist.
#[test]
fn capped_prefetch_sweep_never_worse_than_next_on_block_124m_step() {
    let run = |prefetch: PrefetchHorizon| -> (f64, f64, usize, usize) {
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(8),
                schedule: SchedulePolicy::BatchBySize,
                prefetch,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = record_modeled_124m_block_step(&mut sess);
        let report = sess.execute(&mut plan).unwrap();
        assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-9);
        (
            report.makespan_growth_s,
            report.serial_growth_s,
            report.resident_edges,
            report.elementwise_ops,
        )
    };
    let (m_next, s_next, re_next, el_next) = run(PrefetchHorizon::Next);
    let (m_deep, s_deep, re_deep, el_deep) = run(PrefetchHorizon::Deep);

    // record_modeled prices every non-GEMM op: 25 layernorms + softmax +
    // 12 fused-GELU fc GEMMs, and 37 resident GEMM inputs + the resident
    // softmax input.
    assert_eq!((re_next, el_next), (38, 38));
    assert_eq!((re_deep, el_deep), (38, 38));
    // Identical modeled work under either horizon; the capped sweep may
    // only improve on the one-op hoist, never lose to it.
    assert!((s_next - s_deep).abs() < 1e-9, "same priced work: {s_next} vs {s_deep}");
    assert!(
        m_deep <= m_next + 1e-9,
        "the capped deep sweep must never lose to PrefetchHorizon::Next \
         on the block-level 124M step: deep {m_deep} vs next {m_next}"
    );
}
