//! Cross-layer integration tests.
//!
//! The heavyweight checks: the Rust llm.c port against the JAX train-step
//! artifact (same parameters, same batch → same loss trajectory), the
//! Pallas GEMM artifact against the NPU simulator, and a short end-to-end
//! training run through the full engine stack.

use xdna_repro::coordinator::engine::{EngineConfig, GemmOffloadEngine};
use xdna_repro::model::data::{synthetic_corpus, DataLoader};
use xdna_repro::model::trainer::{train, TrainBackend, TrainConfig};
use xdna_repro::model::{Gpt2Model, ModelConfig};

#[cfg(feature = "pjrt")]
use xdna_repro::coordinator::backend::PjrtGemms;
#[cfg(feature = "pjrt")]
use xdna_repro::coordinator::device::PjrtDevice;
#[cfg(feature = "pjrt")]
use xdna_repro::coordinator::engine::InputLayout;
#[cfg(feature = "pjrt")]
use xdna_repro::gemm::sizes::ProblemSize;
#[cfg(feature = "pjrt")]
use xdna_repro::model::ops::matmul::MatmulDispatch;
#[cfg(feature = "pjrt")]
use xdna_repro::model::PARAM_NAMES;
#[cfg(feature = "pjrt")]
use xdna_repro::runtime::client::{literal_f32, literal_i32, literal_scalar, RuntimeClient};
#[cfg(feature = "pjrt")]
use xdna_repro::runtime::manifest::{default_dir, Manifest};
#[cfg(feature = "pjrt")]
use xdna_repro::util::rng::Rng;

/// JAX flattens dict-pytree arguments in *sorted key order*, which is the
/// ABI the train-step/forward artifacts expose — not the llm.c inventory
/// order of PARAM_NAMES.
#[cfg(feature = "pjrt")]
fn sorted_param_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = PARAM_NAMES.to_vec();
    names.sort();
    names
}

#[cfg(feature = "pjrt")]
fn artifacts_ready() -> bool {
    default_dir().join("manifest.json").exists()
}

/// The full three-layer numerics agreement: L1 Pallas artifact (via PJRT),
/// the Rust NPU simulator, and the bf16 CPU oracle on one GPT-2 size.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_artifact_simulator_and_oracle_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(default_dir()).unwrap();
    let size = ProblemSize::new(256, 768, 768);
    let mut rng = Rng::new(1);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b = vec![0.0f32; size.k * size.n];
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b, 0.0, 0.05);

    // PJRT compute device through the full engine path.
    let pjrt = PjrtGemms::open(manifest).unwrap();
    let mut eng_pjrt = GemmOffloadEngine::new(
        EngineConfig {
            device: Box::new(PjrtDevice::new(pjrt)),
            ..Default::default()
        },
        &[size],
    )
    .unwrap();
    let mut c_pjrt = vec![0.0f32; size.m * size.n];
    eng_pjrt
        .gemm(size, &a, &b, InputLayout::RowMajor, &mut c_pjrt)
        .unwrap();

    // Simulator backend through the same path.
    let mut eng_sim = GemmOffloadEngine::new(EngineConfig::default(), &[size]).unwrap();
    let mut c_sim = vec![0.0f32; size.m * size.n];
    eng_sim
        .gemm(size, &a, &b, InputLayout::RowMajor, &mut c_sim)
        .unwrap();

    // bf16 oracle.
    let mut c_ref = vec![0.0f32; size.m * size.n];
    xdna_repro::gemm::cpu::gemm_bf16_ref(&a, &b, &mut c_ref, size.m, size.k, size.n);

    let d1 = xdna_repro::util::stats::mean_rms_divergence(&c_pjrt, &c_ref);
    let d2 = xdna_repro::util::stats::mean_rms_divergence(&c_sim, &c_ref);
    assert!(d1 < 1e-4, "pallas-vs-oracle {d1}");
    assert!(d2 < 1e-4, "simulator-vs-oracle {d2}");
}

/// Run the JAX train-step artifact with the Rust model's parameters and
/// batch; losses and updated parameters must track the Rust trainer.
#[cfg(feature = "pjrt")]
#[test]
fn jax_train_step_artifact_matches_rust_model() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(default_dir()).unwrap();
    let art = manifest.model("d2").unwrap();
    let cfg = ModelConfig::from_artifact(art);
    let (b, t) = (art.batch, art.seq);

    let mut model = Gpt2Model::new(cfg, 99);
    let mut rng = Rng::new(2);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    // --- JAX side: params/m/v literals + one step. -----------------------
    let mut rt = RuntimeClient::cpu().unwrap();
    let exe = rt.load(manifest.file(&art.train_step_file)).unwrap();
    let shapes = model.params.shapes();
    let mut args: Vec<xla::Literal> = Vec::new();
    for group in 0..3 {
        for name in sorted_param_names() {
            let (off, len) = model.params.tensor_range(name).unwrap();
            let shape = &shapes.iter().find(|(n, _)| *n == name).unwrap().1;
            let data: Vec<f32> = match group {
                0 => model.params.as_slice()[off..off + len].to_vec(),
                _ => vec![0.0; len], // fresh m and v
            };
            args.push(literal_f32(&data, shape).unwrap());
        }
    }
    args.push(literal_scalar(1.0));
    args.push(literal_i32(&tokens, &[b, t]).unwrap());
    args.push(literal_i32(&targets, &[b, t]).unwrap());
    let outs = exe.run_f32(&args).unwrap();
    // Returns params*16, m*16, v*16, loss, grad_norm.
    assert_eq!(outs.len(), 50);
    let jax_loss = outs[48][0];
    let jax_gnorm = outs[49][0];

    // --- Rust side: same params, same batch, one step. --------------------
    let mut dispatch = MatmulDispatch::Cpu;
    let rust_loss = model
        .forward(&mut dispatch, &tokens, Some(&targets), b, t)
        .unwrap()
        .unwrap();
    model.zero_grad();
    model.backward(&mut dispatch).unwrap();
    let opt = xdna_repro::model::ops::adamw::AdamW {
        lr: art.optimizer.lr as f32,
        beta1: art.optimizer.beta1 as f32,
        beta2: art.optimizer.beta2 as f32,
        eps: art.optimizer.eps as f32,
        weight_decay: art.optimizer.weight_decay as f32,
        grad_clip: art.optimizer.grad_clip as f32,
    };
    let rust_gnorm = model.update(&opt);

    assert!(
        (jax_loss - rust_loss).abs() < 2e-3 * rust_loss.abs().max(1.0),
        "loss: jax {jax_loss} vs rust {rust_loss}"
    );
    assert!(
        (jax_gnorm - rust_gnorm).abs() < 0.05 * rust_gnorm.abs().max(0.1),
        "grad norm: jax {jax_gnorm} vs rust {rust_gnorm}"
    );

    // Updated wte must agree elementwise (spot-check a slice). In the
    // sorted-key output order "wte" is the last of the 16 param tensors.
    let wte_idx = sorted_param_names().iter().position(|n| *n == "wte").unwrap();
    let (off, _) = model.params.tensor_range("wte").unwrap();
    let rust_wte = &model.params.as_slice()[off..off + 256];
    let jax_wte = &outs[wte_idx][..256];
    for (i, (r, j)) in rust_wte.iter().zip(jax_wte).enumerate() {
        assert!(
            (r - j).abs() < 5e-4,
            "wte[{i}] diverged: rust {r} vs jax {j}"
        );
    }
}

/// End-to-end: a short training run through the full engine stack reduces
/// the loss, and both reconfig policies produce identical numerics.
#[test]
fn training_through_full_stack_reduces_loss() {
    let cfg = ModelConfig::d2();
    let tc = TrainConfig {
        batch: 2,
        seq: 16,
        epochs: 6,
        steps_per_epoch: 6,
        ..Default::default()
    };
    let corpus = synthetic_corpus(cfg.vocab_size, (2 * 16 + 1) * 32, 13);

    let mut losses = Vec::new();
    for policy in [
        xdna_repro::coordinator::ReconfigPolicy::Minimal,
        xdna_repro::coordinator::ReconfigPolicy::FullArray,
    ] {
        let mut loader = DataLoader::new(corpus.clone(), 2, 16).unwrap();
        let mut model = Gpt2Model::new(cfg, 31);
        let mut eng = GemmOffloadEngine::new(
            EngineConfig {
                policy,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let stats = train(&mut model, &mut loader, &mut TrainBackend::CpuNpu(&mut eng), &tc)
            .unwrap();
        assert!(stats.last().unwrap().loss < stats[0].loss);
        losses.push(stats.last().unwrap().loss);
    }
    // Reconfiguration policy changes timing, never numerics.
    assert_eq!(losses[0], losses[1]);
}

/// Forward-only artifact agrees with the Rust forward pass on logits.
#[cfg(feature = "pjrt")]
#[test]
fn forward_artifact_matches_rust_logits() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(default_dir()).unwrap();
    let art = manifest.model("d2").unwrap();
    let cfg = ModelConfig::from_artifact(art);
    let (b, t) = (art.batch, art.seq);

    let mut model = Gpt2Model::new(cfg, 7);
    let mut rng = Rng::new(3);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    let mut rt = RuntimeClient::cpu().unwrap();
    let exe = rt.load(manifest.file(&art.forward_file)).unwrap();
    let shapes = model.params.shapes();
    let mut args: Vec<xla::Literal> = Vec::new();
    for name in sorted_param_names() {
        let (off, len) = model.params.tensor_range(name).unwrap();
        let shape = &shapes.iter().find(|(n, _)| *n == name).unwrap().1;
        args.push(literal_f32(&model.params.as_slice()[off..off + len], shape).unwrap());
    }
    args.push(literal_i32(&tokens, &[b, t]).unwrap());
    let outs = exe.run_f32(&args).unwrap();
    assert_eq!(outs.len(), 1);
    let jax_logits = &outs[0];

    let mut dispatch = MatmulDispatch::Cpu;
    model.forward(&mut dispatch, &tokens, None, b, t).unwrap();
    let rust_logits = &model.acts.as_ref().unwrap().logits;
    assert_eq!(jax_logits.len(), rust_logits.len());
    let d = xdna_repro::util::stats::mean_rms_divergence(rust_logits, jax_logits);
    assert!(d < 5e-3, "logits divergence {d}");
}
