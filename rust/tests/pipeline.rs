//! Cross-mode / cross-depth / cross-shard equivalence and overlap bounds.
//!
//! The contract of the layered offload API: scheduling, ring depth, and
//! N-dimension sharding may hide time under device work but must never
//! change numerics (bit-identical outputs) and must never make the
//! modeled timeline longer than the strictly serial schedule.

use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    GemmOp, InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
    Ticket, STAGE_RECONFIG,
};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::util::rng::Rng;

fn session(depth: usize, shards: usize, schedule: SchedulePolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards: ShardPolicy::Fixed(Shards(shards)),
            schedule,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions: the same
/// forward / backward-data / backward-weight patterns as the 124M model
/// (including the M-padded vocab size), shrunk so the functional datapath
/// stays fast in CI. The full-scale twelve are covered by the `--ignored`
/// test below.
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Every configuration must produce bit-identical outputs to the depth-1
/// unsharded (strictly serial) reference, per shape.
fn bit_identical_over(sizes: &[ProblemSize]) {
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 1000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        session(1, 1, SchedulePolicy::Fifo)
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
            .unwrap();
        for (depth, shards) in [(2, 1), (4, 1), (1, 4), (4, 4)] {
            let mut c = vec![0.0f32; size.m * size.n];
            session(depth, shards, SchedulePolicy::Fifo)
                .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c)
                .unwrap();
            assert_eq!(
                reference, c,
                "{size}: depth {depth} / {shards} shard(s) must be bit-identical"
            );
        }
    }
}

/// Bit-identical results across depths 1/2/4 and 1/4 shards on every
/// GPT-2 GEMM-site shape.
#[test]
fn depths_and_shards_match_serial_on_all_gpt2_site_shapes() {
    bit_identical_over(&scaled_gpt2_sizes());
}

/// The same check at the paper's actual 124M problem sizes. Heavy (the
/// vocab-sized GEMMs are ~20 GFLOP each); run with
/// `cargo test --release -- --ignored`.
#[test]
#[ignore = "full-scale GPT-2 124M sizes; run with --release -- --ignored"]
fn depths_and_shards_match_serial_on_full_gpt2_sizes() {
    bit_identical_over(&distinct_sizes(&ModelDims::gpt2_124m()));
}

/// Stream all twelve shapes through a ring of the given depth, keeping it
/// full; returns (outputs, makespan, serial, reconfig seconds).
fn stream_all(
    depth: usize,
    shards: usize,
    schedule: SchedulePolicy,
    rounds: usize,
) -> (Vec<Vec<f32>>, f64, f64, f64) {
    let sizes = scaled_gpt2_sizes();
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| random_inputs(s, 2000 + i as u64))
        .collect();
    let mut sess = session(depth, shards, schedule);
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..rounds {
        let mut pending: Vec<(usize, Ticket)> = Vec::new();
        for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            if pending.len() == depth {
                let (j, t) = pending.remove(0);
                sess.wait(t, &mut outs[j]).unwrap();
            }
            let t = sess
                .submit(&GemmOp::new(size).with_b_layout(InputLayout::Transposed), a, b_t)
                .unwrap();
            pending.push((i, t));
        }
        for (j, t) in pending {
            sess.wait(t, &mut outs[j]).unwrap();
        }
    }
    (
        outs,
        sess.pipeline.makespan_s(),
        sess.pipeline.serial_s(),
        sess.modeled_stage_s(STAGE_RECONFIG),
    )
}

/// Interleaved streaming through deeper rings must be bit-identical to
/// serial execution, and the modeled makespan must shrink monotonically:
/// depth 4 <= depth 2 <= the serial sum (never below zero overlap).
#[test]
fn streamed_ring_bit_identical_and_makespan_monotone() {
    let (out1, m1, s1, _) = stream_all(1, 1, SchedulePolicy::Fifo, 1);
    let (out2, m2, s2, _) = stream_all(2, 1, SchedulePolicy::Fifo, 1);
    let (out4, m4, s4, _) = stream_all(4, 1, SchedulePolicy::Fifo, 1);
    assert_eq!(out1, out2, "depth 2 streaming changed numerics");
    assert_eq!(out1, out4, "depth 4 streaming changed numerics");
    // Same stream => identical modeled work.
    assert!((s1 - s2).abs() < 1e-9 && (s2 - s4).abs() < 1e-9);
    assert!((m1 - s1).abs() < 1e-12, "depth 1 is the serial schedule");
    assert!(m2 < s2, "depth 2 must hide some staging");
    assert!(m4 <= m2 + 1e-12, "deeper rings can only help: {m4} vs {m2}");
    assert!(m2 <= m1 + 1e-12);
}

/// Sharded streaming: still bit-identical, still bounded by the serial
/// sum.
#[test]
fn streamed_shards_bit_identical_and_bounded() {
    let (out1, _, _, _) = stream_all(1, 1, SchedulePolicy::Fifo, 1);
    let (out4, m4, s4, _) = stream_all(2, 4, SchedulePolicy::Fifo, 1);
    assert_eq!(out1, out4, "sharded streaming changed numerics");
    assert!(m4 <= s4 + 1e-12, "makespan {m4} must never exceed serial {s4}");
    assert!(m4 < s4, "shards + ring must hide something");
}

/// The reconfig-aware scheduler: on a stream that revisits sizes, batching
/// must spend no more modeled reconfiguration time than FIFO submission
/// order, without changing numerics.
#[test]
fn batching_scheduler_cuts_reconfig_time_not_numerics() {
    // Two rounds of the twelve shapes through a deep ring: the window
    // repeatedly holds revisited sizes the batcher can group.
    let (out_fifo, _, _, reconfig_fifo) = stream_all(6, 1, SchedulePolicy::Fifo, 2);
    let (out_batch, m_batch, s_batch, reconfig_batch) =
        stream_all(6, 1, SchedulePolicy::BatchBySize, 2);
    assert_eq!(out_fifo, out_batch, "scheduling changed numerics");
    assert!(
        reconfig_batch <= reconfig_fifo + 1e-12,
        "batched reconfig {reconfig_batch} must be <= fifo {reconfig_fifo}"
    );
    assert!(m_batch <= s_batch + 1e-12);
}

/// Modeled overlapped time <= modeled serial time, per size, through the
/// legacy engine shim too.
#[test]
fn engine_shim_overlap_never_exceeds_serial() {
    use xdna_repro::coordinator::engine::{EngineConfig, ExecMode, GemmOffloadEngine};
    for &size in &scaled_gpt2_sizes()[..4] {
        let (a, b_t) = random_inputs(size, 777);
        let mut c = vec![0.0f32; size.m * size.n];
        let mut eng = GemmOffloadEngine::new(
            EngineConfig {
                mode: ExecMode::Pipelined,
                ..Default::default()
            },
            &[size],
        )
        .unwrap();
        // Two rounds of paired submissions of the same size (both slots).
        for _ in 0..2 {
            let t1 = eng
                .submit(size, &a, InputLayout::RowMajor, &b_t, InputLayout::Transposed)
                .unwrap();
            let t2 = eng
                .submit(size, &a, InputLayout::RowMajor, &b_t, InputLayout::Transposed)
                .unwrap();
            eng.wait(t1, &mut c).unwrap();
            eng.wait(t2, &mut c).unwrap();
        }
        assert!(
            eng.pipeline.makespan_s() <= eng.pipeline.serial_s() + 1e-12,
            "{size}: overlapped {} > serial {}",
            eng.pipeline.makespan_s(),
            eng.pipeline.serial_s()
        );
        assert!(eng.pipeline.hidden_s() > 0.0, "{size}: expected overlap");
        assert!(eng.pipeline.makespan_s() >= eng.pipeline.device_busy_s);
    }
}
