//! Pipelined-vs-serial engine equivalence and overlap bounds.
//!
//! The contract of the pipelined offload path: scheduling may hide host
//! staging under device work but must never change numerics (bit-identical
//! outputs) and must never make the modeled timeline longer than the
//! strictly serial schedule.

use xdna_repro::coordinator::engine::{EngineConfig, ExecMode, GemmOffloadEngine, InputLayout};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::util::rng::Rng;

fn engine(mode: ExecMode) -> GemmOffloadEngine {
    GemmOffloadEngine::new(
        EngineConfig {
            mode,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions: the same
/// forward / backward-data / backward-weight patterns as the 124M model
/// (including the M-padded vocab size), shrunk so the functional datapath
/// stays fast in CI. The full-scale twelve are covered by the `--ignored`
/// test below.
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N×K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

fn bit_identical_over(sizes: &[ProblemSize]) {
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 1000 + i as u64);
        let mut c_serial = vec![0.0f32; size.m * size.n];
        let mut c_pipe = vec![0.0f32; size.m * size.n];
        engine(ExecMode::Serial)
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c_serial)
            .unwrap();
        engine(ExecMode::Pipelined)
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut c_pipe)
            .unwrap();
        assert_eq!(c_serial, c_pipe, "{size}: modes must be bit-identical");
    }
}

/// Bit-identical results across modes on every GPT-2 GEMM-site shape.
#[test]
fn pipelined_matches_serial_on_all_gpt2_site_shapes() {
    bit_identical_over(&scaled_gpt2_sizes());
}

/// The same check at the paper's actual 124M problem sizes. Heavy (the
/// vocab-sized GEMMs are ~20 GFLOP each); run with
/// `cargo test --release -- --ignored`.
#[test]
#[ignore = "full-scale GPT-2 124M sizes; run with --release -- --ignored"]
fn pipelined_matches_serial_on_full_gpt2_sizes() {
    bit_identical_over(&distinct_sizes(&ModelDims::gpt2_124m()));
}

/// Deep submissions (the backward-pass pairing) must be bit-identical to
/// serial execution too, not just isolated submit+wait.
#[test]
fn interleaved_submissions_bit_identical_to_serial() {
    let sizes = scaled_gpt2_sizes();
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| random_inputs(s, 2000 + i as u64))
        .collect();

    // Serial reference.
    let mut eng = engine(ExecMode::Serial);
    let mut serial_out: Vec<Vec<f32>> = Vec::new();
    for (&size, (a, b_t)) in sizes.iter().zip(&inputs) {
        let mut c = vec![0.0f32; size.m * size.n];
        eng.gemm(size, a, b_t, InputLayout::Transposed, &mut c).unwrap();
        serial_out.push(c);
    }
    let serial_timeline = (eng.pipeline.serial_s(), eng.pipeline.makespan_s());
    assert!(
        (serial_timeline.0 - serial_timeline.1).abs() < 1e-12,
        "serial mode must not overlap"
    );

    // Pipelined: keep two submissions in flight throughout.
    let mut eng = engine(ExecMode::Pipelined);
    let mut pipe_out: Vec<Vec<f32>> = sizes
        .iter()
        .map(|s| vec![0.0f32; s.m * s.n])
        .collect();
    let mut pending: Vec<(usize, xdna_repro::coordinator::Ticket)> = Vec::new();
    for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
        if pending.len() == 2 {
            let (j, t) = pending.remove(0);
            eng.wait(t, &mut pipe_out[j]).unwrap();
        }
        let t = eng
            .submit(size, a, InputLayout::RowMajor, b_t, InputLayout::Transposed)
            .unwrap();
        pending.push((i, t));
    }
    for (j, t) in pending {
        eng.wait(t, &mut pipe_out[j]).unwrap();
    }

    for ((s, p), size) in serial_out.iter().zip(&pipe_out).zip(&sizes) {
        assert_eq!(s, p, "{size}: interleaved pipelining changed numerics");
    }
    // The streamed schedule must have hidden some host staging, and the
    // modeled overlapped time can never exceed the serial sum nor drop
    // below the serialized device spans.
    assert!(eng.pipeline.hidden_s() > 0.0, "no overlap recorded");
    assert!(eng.pipeline.makespan_s() <= eng.pipeline.serial_s());
    assert!(eng.pipeline.makespan_s() >= eng.pipeline.device_busy_s);
}

/// Modeled overlapped time <= modeled serial time, per size and overall.
#[test]
fn overlapped_time_never_exceeds_serial_time() {
    for &size in &scaled_gpt2_sizes() {
        let (a, b_t) = random_inputs(size, 777);
        let mut c = vec![0.0f32; size.m * size.n];
        let mut eng = engine(ExecMode::Pipelined);
        // Two rounds of paired submissions of the same size (both slots).
        for _ in 0..2 {
            let t1 = eng
                .submit(size, &a, InputLayout::RowMajor, &b_t, InputLayout::Transposed)
                .unwrap();
            let t2 = eng
                .submit(size, &a, InputLayout::RowMajor, &b_t, InputLayout::Transposed)
                .unwrap();
            eng.wait(t1, &mut c).unwrap();
            eng.wait(t2, &mut c).unwrap();
        }
        assert!(
            eng.pipeline.makespan_s() <= eng.pipeline.serial_s() + 1e-12,
            "{size}: overlapped {} > serial {}",
            eng.pipeline.makespan_s(),
            eng.pipeline.serial_s()
        );
        assert!(eng.pipeline.hidden_s() > 0.0, "{size}: expected overlap");
    }
}
