//! Record→schedule→execute integration: plan-vs-eager bit-identity on all
//! twelve GPT-2 site shapes, Figure-7 stage fidelity of the depth-1 FIFO
//! plan, whole-step batching across what used to be wait boundaries,
//! auto-shard selection, step makespan monotonicity
//! (plan ≤ eager pipelined ≤ eager serial), the prefetch-horizon ladder
//! (deep ≤ one-op ≤ none, strict on the 124M stream), and plan caching
//! (record once, cache-hit replays bit-identical to a fresh record,
//! invalidation on shape/session change), plus mixed-kind (block-offload)
//! plan divergence and on-disk cache compatibility: a pre-block-offload
//! v1 cache file loads as a recoverable miss, never an error — and so
//! does a truncated file, which the atomic (temp + rename) saver can
//! only leave behind if something else corrupts the cache on disk.

use xdna_repro::coordinator::plan::{PlanCache, PlanOp, PlanOpKind, StepPlan};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    GemmOp, InputLayout, OffloadSession, PrefetchHorizon, QueueDepth, SessionConfig,
    ShardPolicy, Shards, Ticket, STAGES, STAGE_RECONFIG,
};
use xdna_repro::gemm::sizes::{distinct_sizes, gemm_sites, ModelDims, Pass, ProblemSize};
use xdna_repro::model::ops::matmul::MatmulDispatch;
use xdna_repro::model::{Gpt2Model, ModelConfig};
use xdna_repro::util::rng::Rng;

fn session(depth: usize, shards: ShardPolicy, schedule: SchedulePolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards,
            schedule,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

fn fixed(n: usize) -> ShardPolicy {
    ShardPolicy::Fixed(Shards(n))
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions (same
/// forward / backward-data / backward-weight patterns as the 124M model).
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Recording through a deep, auto-sharded, batch-scheduled session must
/// produce bit-for-bit the eager depth-1 unsharded outputs on every GPT-2
/// site shape.
#[test]
fn plan_bit_identical_to_eager_serial_on_all_gpt2_site_shapes() {
    let sizes = scaled_gpt2_sizes();
    let mut planned = session(4, ShardPolicy::Auto, SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    let mut plan_outs: Vec<Vec<f32>> =
        sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for (i, (&size, out)) in sizes.iter().zip(plan_outs.iter_mut()).enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let op = PlanOp::new(size)
            .with_b_layout(InputLayout::Transposed)
            .prefetchable_b(true);
        planned.record_gemm(&mut plan, &op, &a, &b_t, out).unwrap();
    }
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        session(1, fixed(1), SchedulePolicy::Fifo)
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
            .unwrap();
        assert_eq!(
            reference, plan_outs[i],
            "{size}: recorded output must be bit-identical to eager serial"
        );
    }
    let report = planned.execute(&mut plan).unwrap();
    assert_eq!(report.stats.len(), 12);
    assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
}

/// A depth-1 unsharded FIFO plan replays the paper's strictly serial
/// Figure-7 schedule: identical per-stage modeled totals, timeline, and
/// stage sequence as driving the same stream eagerly.
#[test]
fn depth1_fifo_plan_reproduces_figure7_stage_sequence() {
    let sizes = scaled_gpt2_sizes();

    let mut eager = session(1, fixed(1), SchedulePolicy::Fifo);
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 5000 + i as u64);
        let mut c = vec![0.0f32; size.m * size.n];
        eager.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
    }

    let mut planned = session(1, fixed(1), SchedulePolicy::Fifo);
    let mut plan = StepPlan::new();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for (i, (&size, out)) in sizes.iter().zip(outs.iter_mut()).enumerate() {
        let (a, b_t) = random_inputs(size, 5000 + i as u64);
        // The Figure-7 chain: each invocation strictly after the previous.
        let mut op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
        if let Some(h) = plan.chain_head() {
            op = op.after(h);
        }
        let n = planned.record_gemm(&mut plan, &op, &a, &b_t, out).unwrap();
        plan.set_chain(n);
    }
    let report = planned.execute(&mut plan).unwrap();
    assert_eq!(report.order, (0..12).collect::<Vec<_>>());
    assert_eq!(report.prefetched, 0);
    for stage in STAGES {
        assert_eq!(
            planned.modeled_stage_s(stage),
            eager.modeled_stage_s(stage),
            "stage '{stage}' must accumulate identically"
        );
    }
    assert_eq!(planned.pipeline.makespan_s(), eager.pipeline.makespan_s());
    assert_eq!(planned.pipeline.serial_s(), eager.pipeline.serial_s());
    assert_eq!(planned.pipeline.hidden_s(), 0.0, "strictly serial: no overlap");
    assert_eq!(planned.invocations, eager.invocations);
}

/// The plan window spans the whole step, so BatchBySize groups same-size
/// ops that an eager ring could never see together (they were separated by
/// wait boundaries).
#[test]
fn whole_step_batching_cuts_reconfigs_across_wait_boundaries() {
    let sizes = scaled_gpt2_sizes();
    let rounds = 2;

    // Eager ring: depth-4 window, BatchBySize — revisited sizes are 12
    // submissions apart, far outside the window.
    let mut eager = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| random_inputs(s, 6000 + i as u64))
        .collect();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..rounds {
        let mut pending: Vec<(usize, Ticket)> = Vec::new();
        for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            if pending.len() == 4 {
                let (j, t) = pending.remove(0);
                eager.wait(t, &mut outs[j]).unwrap();
            }
            let t = eager
                .submit(&GemmOp::new(size).with_b_layout(InputLayout::Transposed), a, b_t)
                .unwrap();
            pending.push((i, t));
        }
        for (j, t) in pending {
            eager.wait(t, &mut outs[j]).unwrap();
        }
    }
    let eager_reconfig = eager.modeled_stage_s(STAGE_RECONFIG);

    let mut planned = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    for _ in 0..rounds {
        for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            let op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
            planned
                .record_gemm(&mut plan, &op, a, b_t, &mut outs[i])
                .unwrap();
        }
    }
    let report = planned.execute(&mut plan).unwrap();
    let plan_reconfig = planned.modeled_stage_s(STAGE_RECONFIG);
    assert!(
        plan_reconfig < eager_reconfig,
        "whole-step batching must strictly cut reconfig time: plan {plan_reconfig} \
         vs eager ring {eager_reconfig}"
    );
    assert_eq!(
        report.reconfigs, 12,
        "each distinct size reconfigures once across the whole step"
    );
}

/// Auto-shard selection stays bit-identical on every site shape and its
/// modeled single-invocation schedule is never worse than unsharded.
#[test]
fn auto_sharding_bit_identical_and_no_worse_on_all_gpt2_site_shapes() {
    for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
        let (a, b_t) = random_inputs(size, 7000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        let mut unsharded = session(1, fixed(1), SchedulePolicy::Fifo);
        unsharded
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
            .unwrap();
        let mut auto = session(1, ShardPolicy::Auto, SchedulePolicy::Fifo);
        let mut c = vec![0.0f32; size.m * size.n];
        auto.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
        assert_eq!(reference, c, "{size}: auto sharding must be bit-identical");
        assert!(
            auto.pipeline.makespan_s() <= unsharded.pipeline.makespan_s() + 1e-12,
            "{size}: auto ({} strips) modeled worse than unsharded",
            auto.shards_for(size).unwrap()
        );
    }
}

/// The acceptance chain on a real training step: recording the whole step
/// and scheduling it with prefetch + BatchBySize is modeled no slower than
/// the eager pipelined (depth-2) schedule, which is no slower than the
/// strictly serial (depth-1) schedule — and strictly faster end to end,
/// driven by the backward pairs and the batched reconfigurations. Numerics
/// stay bit-identical throughout.
#[test]
fn step_makespan_monotone_plan_le_eager_pipelined_le_serial() {
    let cfg = ModelConfig::d4();
    let (b, t) = (2usize, 16usize);
    let mut rng = Rng::new(17);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    let step_eager = |depth: usize| -> (f32, Vec<f32>, f64, f64) {
        let mut model = Gpt2Model::new(cfg, 321);
        let mut sess = session(depth, fixed(1), SchedulePolicy::Fifo);
        let loss = model
            .forward(&mut MatmulDispatch::Npu(&mut sess), &tokens, Some(&targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut MatmulDispatch::Npu(&mut sess)).unwrap();
        (
            loss,
            model.grads.as_slice().to_vec(),
            sess.pipeline.makespan_s(),
            sess.pipeline.serial_s(),
        )
    };
    let (loss1, grads1, m1, s1) = step_eager(1);
    let (loss2, grads2, m2, s2) = step_eager(2);

    let mut model = Gpt2Model::new(cfg, 321);
    let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    let loss_p = {
        let mut d = MatmulDispatch::Plan {
            session: &mut sess,
            plan: &mut plan,
        };
        let l = model
            .forward(&mut d, &tokens, Some(&targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut d).unwrap();
        l
    };
    let report = sess.execute(&mut plan).unwrap();
    let (m_plan, s_plan) = (sess.pipeline.makespan_s(), sess.pipeline.serial_s());

    // Bit-identity across every schedule.
    assert_eq!(loss1, loss2);
    assert_eq!(loss1, loss_p);
    assert_eq!(grads1, grads2);
    assert_eq!(grads1, model.grads.as_slice());

    // Same modeled work at both eager depths; the batched plan's serial
    // sum can only shrink further (it removes reconfiguration barriers,
    // never stage work).
    assert!((s1 - s2).abs() < 1e-9, "serial sums must match: {s1} vs {s2}");
    assert!(s_plan <= s2 + 1e-9, "batching may only remove work: {s_plan} vs {s2}");
    // ...monotonically better scheduled.
    assert!((m1 - s1).abs() < 1e-12, "depth 1 is the strictly serial schedule");
    assert!(m2 <= m1 + 1e-12, "pipelining can only help: {m2} vs {m1}");
    assert!(
        m_plan < m2,
        "whole-step plan must be strictly faster than the eager pipelined \
         schedule: {m_plan} vs {m2}"
    );
    assert!(report.prefetched > 0, "forward weights must prefetch");
    assert!(report.reconfigs > 0);
    assert!(report.hidden_growth_s() > 0.0);
}

/// Drive one step over all twelve GPT-2 site shapes through `drive`,
/// which maps (PlanOp, a, b, out) per shape — shared by the record and
/// replay sides of the cache tests.
fn twelve_shape_step(
    mut drive: impl FnMut(&PlanOp, &[f32], &[f32], &mut [f32]),
) -> Vec<Vec<f32>> {
    let sizes = scaled_gpt2_sizes();
    let mut outs = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 8000 + i as u64);
        let op = PlanOp::new(size)
            .with_b_layout(InputLayout::Transposed)
            .prefetchable_b(true);
        let mut c = vec![0.0f32; size.m * size.n];
        drive(&op, &a, &b_t, &mut c);
        outs.push(c);
    }
    outs
}

/// The tentpole acceptance: a cached run records exactly once, and every
/// later step is a cache-hit replay that is bit-identical — numerics and
/// modeled timeline — to re-recording the step from scratch, across all
/// twelve GPT-2 site shapes.
#[test]
fn cache_hit_replay_bit_identical_to_fresh_record_on_all_gpt2_site_shapes() {
    let mut cached = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let mut fresh = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let mut cache = PlanCache::new();

    // Step 1 on both sessions: record + execute (identical work).
    let mut plan_c = StepPlan::new();
    let outs_c1 = twelve_shape_step(|op, a, b, c| {
        cached.record_gemm(&mut plan_c, op, a, b, c).unwrap();
    });
    cached.execute(&mut plan_c).unwrap();
    cache.insert(cached.freeze(plan_c).unwrap());
    let mut plan_f = StepPlan::new();
    let outs_f1 = twelve_shape_step(|op, a, b, c| {
        fresh.record_gemm(&mut plan_f, op, a, b, c).unwrap();
    });
    fresh.execute(&mut plan_f).unwrap();
    assert_eq!(outs_c1, outs_f1);

    // Steps 2 and 3: `cached` replays the frozen schedule, `fresh`
    // re-records every time. Bit-identical throughout.
    for _ in 0..2 {
        let mut replay = cached.begin_replay(&cache).expect("entry cached");
        let outs_c = twelve_shape_step(|op, a, b, c| {
            cached.replay_gemm(&mut replay, op, a, b, c).unwrap();
        });
        let rep_c = cached.finish_replay(replay).unwrap();
        cache.record_hit();

        let mut plan = StepPlan::new();
        let outs_f = twelve_shape_step(|op, a, b, c| {
            fresh.record_gemm(&mut plan, op, a, b, c).unwrap();
        });
        let rep_f = fresh.execute(&mut plan).unwrap();

        assert_eq!(outs_c, outs_f, "cache-hit numerics must be the fresh-record numerics");
        assert_eq!(rep_c.order, rep_f.order, "frozen order is the steady-state order");
        assert_eq!(rep_c.reconfigs, rep_f.reconfigs);
        assert_eq!(rep_c.prefetched, rep_f.prefetched);
        assert!(
            (rep_c.makespan_growth_s - rep_f.makespan_growth_s).abs() < 1e-12,
            "cache-hit timeline must match a fresh record: {} vs {}",
            rep_c.makespan_growth_s,
            rep_f.makespan_growth_s
        );
        assert!((rep_c.serial_growth_s - rep_f.serial_growth_s).abs() < 1e-12);
    }
    assert_eq!((cache.hits(), cache.misses()), (2, 1), "recorded once, replayed twice");
    assert!(
        (cached.pipeline.makespan_s() - fresh.pipeline.makespan_s()).abs() < 1e-12,
        "whole-run timelines must agree: {} vs {}",
        cached.pipeline.makespan_s(),
        fresh.pipeline.makespan_s()
    );
    assert_eq!(cached.invocations, fresh.invocations);
}

/// Invalidation: a shape change diverges recoverably (the trainer
/// re-records), and entries are session-scoped like tickets.
#[test]
fn plan_cache_invalidates_on_shape_change_and_is_session_scoped() {
    let mut s1 = session(2, fixed(1), SchedulePolicy::Fifo);
    let mut cache = PlanCache::new();
    let mut plan = StepPlan::new();
    twelve_shape_step(|op, a, b, c| {
        s1.record_gemm(&mut plan, op, a, b, c).unwrap();
    });
    s1.execute(&mut plan).unwrap();
    cache.insert(s1.freeze(plan).unwrap());

    // Same session, different shape stream: divergence at the first op.
    let wrong = ProblemSize::new(96, 64, 128);
    let wrong_op = PlanOp::new(wrong);
    let a = vec![1.0f32; 96 * 64];
    let b = vec![0.5f32; 64 * 128];
    let mut c = vec![0.0f32; 96 * 128];
    let mut replay = s1.begin_replay(&cache).unwrap();
    let err = s1.replay_gemm(&mut replay, &wrong_op, &a, &b, &mut c).unwrap_err();
    assert!(err.is_plan_divergence(), "{err}");
    assert!(err.to_string().contains("re-record"), "{err}");
    // After re-recording the changed step, the cache holds both shapes.
    let mut plan2 = StepPlan::new();
    s1.record_gemm(&mut plan2, &wrong_op, &a, &b, &mut c).unwrap();
    s1.execute(&mut plan2).unwrap();
    cache.insert(s1.freeze(plan2).unwrap());
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.misses(), 2);

    // Another session (different config counts as a different session):
    // replaying its entry errors helpfully, and the optimistic path
    // simply records.
    let s2 = session(2, fixed(4), SchedulePolicy::Fifo);
    let entry = cache.latest().unwrap();
    let err = s2.replay_entry(entry).unwrap_err().to_string();
    assert!(err.contains("session-scoped"), "{err}");
    assert!(s2.begin_replay(&cache).is_none(), "nothing cached for session 2");
}

/// The prefetch-horizon ladder on a real recorded GPT-2 (d4) training
/// step: deep ≤ one-op ≤ no prefetch. (Deep simulates the one-op
/// schedule too and charges the better, so the first inequality is
/// structural; strictness is asserted on the 124M stream below, where
/// host-bound staging gives the deep horizon room to win.)
#[test]
fn prefetch_horizon_ladder_on_recorded_gpt2_step() {
    let cfg = ModelConfig::d4();
    let (b, t) = (2usize, 16usize);
    let mut rng = Rng::new(29);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    let step = |prefetch: PrefetchHorizon| -> (f32, f64) {
        let mut model = Gpt2Model::new(cfg, 77);
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(4),
                schedule: SchedulePolicy::BatchBySize,
                prefetch,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = StepPlan::new();
        let loss = {
            let mut d = MatmulDispatch::Plan {
                session: &mut sess,
                plan: &mut plan,
            };
            let l = model
                .forward(&mut d, &tokens, Some(&targets), b, t)
                .unwrap()
                .unwrap();
            model.zero_grad();
            model.backward(&mut d).unwrap();
            l
        };
        let report = sess.execute(&mut plan).unwrap();
        assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
        (loss, report.makespan_growth_s)
    };
    let (l_none, m_none) = step(PrefetchHorizon::None);
    let (l_next, m_next) = step(PrefetchHorizon::Next);
    let (l_deep, m_deep) = step(PrefetchHorizon::Deep);
    assert_eq!(l_none, l_next, "prefetch horizon must never change numerics");
    assert_eq!(l_none, l_deep);
    assert!(m_next <= m_none + 1e-15, "one-op hoist may only help: {m_next} vs {m_none}");
    assert!(m_deep <= m_next + 1e-15, "deep horizon may only help: {m_deep} vs {m_next}");
    assert!(m_next < m_none, "the d4 step has weights to hoist: {m_next} vs {m_none}");
}

/// Build the full GPT-2 124M step's GEMM stream (forward chain, then the
/// backward (dinp, dW) pairs in reverse layer order, exactly the
/// trainer's record pattern) as a *modeled* plan — the dry-run record
/// path prices the 154 MB lm-head staging without allocating it.
fn record_chained(
    sess: &mut OffloadSession,
    plan: &mut StepPlan,
    size: ProblemSize,
    a_layout: InputLayout,
    b_layout: InputLayout,
) {
    let mut op = PlanOp::new(size)
        .with_a_layout(a_layout)
        .with_b_layout(b_layout)
        .prefetchable_b(true);
    if let Some(h) = plan.chain_head() {
        op = op.after(h);
    }
    let n = sess.record_modeled(plan, &op).unwrap();
    plan.set_chain(n);
}

/// The backward (dinp, dW) pair of one site: dinp advances the chain, dW
/// is a leaf; both B inputs (weight, saved activation) are known ahead.
fn record_backward_pair(
    sess: &mut OffloadSession,
    plan: &mut StepPlan,
    dinp_size: ProblemSize,
    dw_size: ProblemSize,
) {
    let head = plan.chain_head();
    let mut op_dinp = PlanOp::new(dinp_size).prefetchable_b(true);
    let mut op_dw = PlanOp::new(dw_size)
        .with_a_layout(InputLayout::Transposed)
        .prefetchable_b(true);
    if let Some(h) = head {
        op_dinp = op_dinp.after(h);
        op_dw = op_dw.after(h);
    }
    let n = sess.record_modeled(plan, &op_dinp).unwrap();
    sess.record_modeled(plan, &op_dw).unwrap();
    plan.set_chain(n);
}

fn record_modeled_124m_step(sess: &mut OffloadSession) -> StepPlan {
    let sites = gemm_sites(&ModelDims::gpt2_124m());
    let fwd: Vec<_> = sites.iter().filter(|s| s.pass == Pass::Forward).collect();
    let layers = fwd[0].count;
    let mut plan = StepPlan::new();
    // Forward: per layer qkv → attproj → fc → fcproj, then the lm head —
    // one activation chain, weights (B, transposed) known ahead.
    for _ in 0..layers {
        for site in fwd.iter().filter(|s| s.count == layers) {
            record_chained(
                sess,
                &mut plan,
                site.size,
                InputLayout::RowMajor,
                InputLayout::Transposed,
            );
        }
    }
    let lm = fwd.iter().find(|s| s.count == 1).expect("lm head");
    record_chained(
        sess,
        &mut plan,
        lm.size,
        InputLayout::RowMajor,
        InputLayout::Transposed,
    );
    // Backward: lm head first, then layers in reverse, exactly the
    // trainer's record order.
    let bwd_data: Vec<_> = sites.iter().filter(|s| s.pass == Pass::BackwardData).collect();
    let bwd_w: Vec<_> = sites.iter().filter(|s| s.pass == Pass::BackwardWeight).collect();
    let pair_sizes = |op_name: &str| -> (ProblemSize, ProblemSize) {
        (
            bwd_data.iter().find(|s| s.op == op_name).unwrap().size,
            bwd_w.iter().find(|s| s.op == op_name).unwrap().size,
        )
    };
    let (dinp, dw) = pair_sizes("lm_head");
    record_backward_pair(sess, &mut plan, dinp, dw);
    for _ in 0..layers {
        for name in ["fcproj", "fc", "attproj", "qkv"] {
            let (dinp, dw) = pair_sizes(name);
            record_backward_pair(sess, &mut plan, dinp, dw);
        }
    }
    plan
}

/// The deep prefetch horizon must *strictly* beat the PR-3 one-op hoist
/// on the GPT-2 124M step: at full scale the fat weight stagings
/// (lm-head B alone is 154 MB, ~13 ms of transpose) are host-bound
/// behind small-idle invocations, while the lm-head and dW kernels leave
/// multi-millisecond idle windows — a one-op horizon fills each window
/// with at most one staging, the deep horizon packs several.
#[test]
fn deep_horizon_strictly_beats_one_op_on_the_gpt2_124m_step() {
    let run = |prefetch: PrefetchHorizon| -> (f64, f64) {
        let mut sess = OffloadSession::new(
            SessionConfig {
                depth: QueueDepth(4),
                prefetch,
                ..Default::default()
            },
            &[],
        )
        .unwrap();
        let mut plan = record_modeled_124m_step(&mut sess);
        let report = sess.execute(&mut plan).unwrap();
        assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-9);
        (report.makespan_growth_s, report.serial_growth_s)
    };
    let (m_none, s_none) = run(PrefetchHorizon::None);
    let (m_next, s_next) = run(PrefetchHorizon::Next);
    let (m_deep, s_deep) = run(PrefetchHorizon::Deep);
    // Identical modeled work in every schedule.
    assert!((s_none - s_next).abs() < 1e-9 && (s_next - s_deep).abs() < 1e-9);
    // The ladder, strict where the win lives.
    assert!(m_next < m_none, "one-op hoist must hide staging: {m_next} vs {m_none}");
    assert!(
        m_deep + 1e-6 < m_next,
        "the deep horizon must strictly beat the one-op hoist on the 124M step: \
         deep {m_deep} vs one-op {m_next}"
    );
}

/// Record a small mixed-kind (block-offload) step: a layernorm feeding a
/// device-resident GEMM feeding a resident softmax — the shortest chain
/// that exercises every non-GEMM divergence axis.
fn record_mixed_step(
    sess: &mut OffloadSession,
    a: &[f32],
    b_t: &[f32],
    c: &mut [f32],
) -> StepPlan {
    let size = ProblemSize::new(64, 64, 128);
    let mut plan = StepPlan::new();
    let ln = PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(64, 1, 64));
    let n0 = sess.record_elementwise(&mut plan, &ln).unwrap();
    let gemm = PlanOp::new(size)
        .with_b_layout(InputLayout::Transposed)
        .prefetchable_b(true)
        .resident_input(true)
        .after(n0);
    let n1 = sess.record_gemm(&mut plan, &gemm, a, b_t, c).unwrap();
    let sm = PlanOp::elementwise(PlanOpKind::Softmax, ProblemSize::new(64, 1, 128))
        .resident_input(true)
        .after(n1);
    sess.record_elementwise(&mut plan, &sm).unwrap();
    plan
}

/// Mixed-kind divergence: replaying a cached block-offload step against
/// a changed elementwise shape, a changed op *kind* (a GEMM where the
/// layernorm was), or a changed residency (occupancy) all diverge
/// recoverably — and re-recording the changed step caches both variants.
#[test]
fn mixed_kind_plan_diverges_recoverably_on_shape_kind_or_residency_change() {
    let size = ProblemSize::new(64, 64, 128);
    let (a, b_t) = random_inputs(size, 9100);
    let mut c = vec![0.0f32; size.m * size.n];
    let mut sess = session(2, fixed(1), SchedulePolicy::Fifo);
    let mut cache = PlanCache::new();
    let mut plan = record_mixed_step(&mut sess, &a, &b_t, &mut c);
    sess.execute(&mut plan).unwrap();
    cache.insert(sess.freeze(plan).unwrap());

    // Shape change at the elementwise cursor.
    let mut replay = sess.begin_replay(&cache).unwrap();
    let wrong_shape = PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(96, 1, 64));
    let err = sess.replay_elementwise(&mut replay, &wrong_shape).unwrap_err();
    assert!(err.is_plan_divergence(), "{err}");
    assert!(err.to_string().contains("re-record"), "{err}");
    drop(replay);

    // Kind change: a GEMM arrives where the cached op is a layernorm.
    let mut replay = sess.begin_replay(&cache).unwrap();
    let err = sess
        .replay_gemm(&mut replay, &PlanOp::new(size), &a, &b_t, &mut c)
        .unwrap_err();
    assert!(err.is_plan_divergence(), "kind change must diverge recoverably: {err}");
    drop(replay);

    // Residency (occupancy) change on the same shape and kind.
    let mut replay = sess.begin_replay(&cache).unwrap();
    let resident_ln = PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(64, 1, 64))
        .resident_input(true);
    let err = sess.replay_elementwise(&mut replay, &resident_ln).unwrap_err();
    assert!(err.is_plan_divergence(), "residency change must diverge recoverably: {err}");
    drop(replay);

    // The session stays usable: re-record the changed step (the new
    // layernorm shape feeding the same GEMM) and both variants coexist
    // in the cache.
    let mut plan2 = StepPlan::new();
    let ln96 = PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(96, 1, 64));
    let n0 = sess.record_elementwise(&mut plan2, &ln96).unwrap();
    let gemm2 = PlanOp::new(size)
        .with_b_layout(InputLayout::Transposed)
        .prefetchable_b(true)
        .after(n0);
    sess.record_gemm(&mut plan2, &gemm2, &a, &b_t, &mut c).unwrap();
    sess.execute(&mut plan2).unwrap();
    cache.insert(sess.freeze(plan2).unwrap());
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.misses(), 2);
}

/// A mixed-kind step survives the on-disk cache roundtrip: kinds, fused
/// epilogues, and residency flags serialize with the v2 format, and the
/// reloaded entry replays without divergence.
#[test]
fn mixed_kind_plan_survives_the_on_disk_cache_roundtrip() {
    let path = std::env::temp_dir().join("xdna_plan_cache_mixed_roundtrip.json");
    let path = path.to_str().unwrap().to_string();
    let size = ProblemSize::new(64, 64, 128);
    let (a, b_t) = random_inputs(size, 9200);
    let mut c = vec![0.0f32; size.m * size.n];
    let mut sess = session(2, fixed(1), SchedulePolicy::Fifo);
    let mut cache = PlanCache::new();
    let mut plan = record_mixed_step(&mut sess, &a, &b_t, &mut c);
    sess.execute(&mut plan).unwrap();
    cache.insert(sess.freeze(plan).unwrap());
    let fp = 0xb10c_0ff1u64; // arbitrary fingerprint
    assert_eq!(cache.save_to(&path, fp, sess.session_id()).unwrap(), 1);

    // A fresh cache (a restarted process) adopts the entry and the
    // replay runs the whole mixed-kind chain against it.
    let mut loaded = PlanCache::new();
    assert_eq!(loaded.load_from(&path, fp, sess.session_id()), 1);
    let mut replay = sess.begin_replay(&loaded).expect("adopted entry replayable");
    let ln = PlanOp::elementwise(PlanOpKind::LayerNorm, ProblemSize::new(64, 1, 64));
    let n0 = sess.replay_elementwise(&mut replay, &ln).unwrap();
    let gemm = PlanOp::new(size)
        .with_b_layout(InputLayout::Transposed)
        .prefetchable_b(true)
        .resident_input(true)
        .after(n0);
    let mut c2 = vec![0.0f32; size.m * size.n];
    let n1 = sess.replay_gemm(&mut replay, &gemm, &a, &b_t, &mut c2).unwrap();
    let sm = PlanOp::elementwise(PlanOpKind::Softmax, ProblemSize::new(64, 1, 128))
        .resident_input(true)
        .after(n1);
    sess.replay_elementwise(&mut replay, &sm).unwrap();
    let report = sess.finish_replay(replay).unwrap();
    assert_eq!(report.stats.len(), 3);
    assert!(report.resident_edges > 0 && report.elementwise_ops > 0);
    assert_eq!(c2, c, "replayed GEMM numerics track the recorded data");
    std::fs::remove_file(&path).ok();
}

/// A pre-block-offload (v1) cache file — old format version, op records
/// without the kind/fused/residency fields — is a *recoverable miss*:
/// zero entries adopted, no error, and the run records its first step as
/// if no file existed. A v2 file carrying an unknown op kind is likewise
/// skipped entry-by-entry rather than erroring.
#[test]
fn pre_block_offload_v1_cache_file_is_a_recoverable_miss() {
    let sess = session(2, fixed(1), SchedulePolicy::Fifo);
    let fp = 0x00c0_ffeeu64;

    // A faithful v1 entry: exactly the pre-block-offload writer's keys —
    // no `kind`, `fused`, `resident_a`, or `resident_c` anywhere.
    let v1 = r#"{
  "format_version": 1,
  "generator": "xdna-repro plan cache",
  "fingerprint": "0000000000c0ffee",
  "entries": [{
    "order": [0],
    "choice": "next",
    "ops": [{
      "size": [64, 64, 128],
      "strip_size": [64, 64, 128],
      "a_layout": "row-major",
      "b_layout": "transposed",
      "deps": [],
      "prefetch_b": true,
      "host_a_s": 0.001,
      "host_b_s": 0.001,
      "sync_in_s": 0.0005,
      "reconfig_switch_s": 0.001,
      "reconfig_once_s": 0.004,
      "strips": [[0.002, 0.0004]],
      "host_post_s": 0.0002,
      "energy_j": 0.01,
      "wall_s": 0.0
    }]
  }]
}"#;
    let path = std::env::temp_dir().join("xdna_plan_cache_v1_miss.json");
    let path = path.to_str().unwrap().to_string();
    std::fs::write(&path, v1).unwrap();
    let mut cache = PlanCache::new();
    assert_eq!(
        cache.load_from(&path, fp, sess.session_id()),
        0,
        "a v1 file must load as a clean miss"
    );
    assert!(cache.is_empty());
    assert!(
        sess.begin_replay(&cache).is_none(),
        "the run records its first step as if no file existed"
    );
    std::fs::remove_file(&path).ok();

    // Current format version but an op kind this build does not know:
    // the corrupt entry is skipped, never an error.
    let v2_unknown_kind = r#"{
  "format_version": 2,
  "generator": "xdna-repro plan cache",
  "fingerprint": "0000000000c0ffee",
  "entries": [{
    "order": [0],
    "choice": "next",
    "ops": [{
      "size": [64, 64, 128],
      "kind": "conv",
      "fused": "none",
      "resident_a": false,
      "resident_c": false,
      "strip_size": [64, 64, 128],
      "a_layout": "row-major",
      "b_layout": "transposed",
      "deps": [],
      "prefetch_b": true,
      "host_a_s": 0.001,
      "host_b_s": 0.001,
      "sync_in_s": 0.0005,
      "reconfig_switch_s": 0.001,
      "reconfig_once_s": 0.004,
      "strips": [[0.002, 0.0004]],
      "host_post_s": 0.0002,
      "energy_j": 0.01,
      "wall_s": 0.0
    }]
  }]
}"#;
    let path = std::env::temp_dir().join("xdna_plan_cache_v2_unknown_kind.json");
    let path = path.to_str().unwrap().to_string();
    std::fs::write(&path, v2_unknown_kind).unwrap();
    let mut cache = PlanCache::new();
    assert_eq!(
        cache.load_from(&path, fp, sess.session_id()),
        0,
        "an unknown op kind skips the entry rather than erroring"
    );
    assert!(cache.is_empty());
    std::fs::remove_file(&path).ok();
}

/// A truncated cache file — a crash mid-write by a non-atomic writer, or
/// on-disk corruption — is a *recoverable miss*: the loader adopts zero
/// entries, never errors, and the run records its first step as if no
/// file existed. The saver itself can't produce one: it writes a temp
/// file and renames it over the target, leaving no temp file behind on
/// success — so the next save simply heals the corrupt path.
#[test]
fn truncated_cache_file_is_a_recoverable_miss_and_saves_are_atomic() {
    let path = std::env::temp_dir().join("xdna_plan_cache_truncated.json");
    let path = path.to_str().unwrap().to_string();
    let size = ProblemSize::new(64, 64, 128);
    let (a, b_t) = random_inputs(size, 9300);
    let mut c = vec![0.0f32; size.m * size.n];
    let mut sess = session(2, fixed(1), SchedulePolicy::Fifo);
    let mut cache = PlanCache::new();
    let op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
    let mut plan = StepPlan::new();
    sess.record_gemm(&mut plan, &op, &a, &b_t, &mut c).unwrap();
    sess.execute(&mut plan).unwrap();
    cache.insert(sess.freeze(plan).unwrap());
    let fp = 0x0dd0_b175u64;
    assert_eq!(cache.save_to(&path, fp, sess.session_id()).unwrap(), 1);
    assert!(
        !std::path::Path::new(&format!("{path}.tmp")).exists(),
        "the atomic saver must not leave its temp file behind"
    );

    // Chop the file mid-JSON (what a crash inside a naive writer would
    // leave): the loader reports a clean miss.
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.len() > 2);
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let mut loaded = PlanCache::new();
    assert_eq!(
        loaded.load_from(&path, fp, sess.session_id()),
        0,
        "a truncated file must load as a clean miss"
    );
    assert!(loaded.is_empty());
    assert!(
        sess.begin_replay(&loaded).is_none(),
        "the run records its first step as if no file existed"
    );

    // The run proceeds: record, freeze, and the next save heals the path.
    let mut plan2 = StepPlan::new();
    sess.record_gemm(&mut plan2, &op, &a, &b_t, &mut c).unwrap();
    sess.execute(&mut plan2).unwrap();
    loaded.insert(sess.freeze(plan2).unwrap());
    assert_eq!(loaded.save_to(&path, fp, sess.session_id()).unwrap(), 1);
    let mut healed = PlanCache::new();
    assert_eq!(healed.load_from(&path, fp, sess.session_id()), 1);
    std::fs::remove_file(&path).ok();
}
