//! Record→schedule→execute integration: plan-vs-eager bit-identity on all
//! twelve GPT-2 site shapes, Figure-7 stage fidelity of the depth-1 FIFO
//! plan, whole-step batching across what used to be wait boundaries,
//! auto-shard selection, and step makespan monotonicity
//! (plan ≤ eager pipelined ≤ eager serial).

use xdna_repro::coordinator::plan::{PlanOp, StepPlan};
use xdna_repro::coordinator::scheduler::SchedulePolicy;
use xdna_repro::coordinator::session::{
    GemmOp, InputLayout, OffloadSession, QueueDepth, SessionConfig, ShardPolicy, Shards,
    Ticket, STAGES, STAGE_RECONFIG,
};
use xdna_repro::gemm::sizes::{distinct_sizes, ModelDims, ProblemSize};
use xdna_repro::model::ops::matmul::MatmulDispatch;
use xdna_repro::model::{Gpt2Model, ModelConfig};
use xdna_repro::util::rng::Rng;

fn session(depth: usize, shards: ShardPolicy, schedule: SchedulePolicy) -> OffloadSession {
    OffloadSession::new(
        SessionConfig {
            depth: QueueDepth(depth),
            shards,
            schedule,
            ..Default::default()
        },
        &[],
    )
    .unwrap()
}

fn fixed(n: usize) -> ShardPolicy {
    ShardPolicy::Fixed(Shards(n))
}

/// All twelve GPT-2 GEMM-site shapes at reduced model dimensions (same
/// forward / backward-data / backward-weight patterns as the 124M model).
fn scaled_gpt2_sizes() -> Vec<ProblemSize> {
    let dims = ModelDims {
        batch: 1,
        seq: 64,
        channels: 128,
        padded_vocab: 1024,
        layers: 2,
    };
    let sizes = distinct_sizes(&dims);
    assert_eq!(sizes.len(), 12, "scaled dims must keep all twelve shapes");
    sizes
}

fn random_inputs(size: ProblemSize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; size.m * size.k];
    let mut b_t = vec![0.0f32; size.n * size.k]; // N x K: forces the transpose
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut b_t, 0.0, 0.1);
    (a, b_t)
}

/// Recording through a deep, auto-sharded, batch-scheduled session must
/// produce bit-for-bit the eager depth-1 unsharded outputs on every GPT-2
/// site shape.
#[test]
fn plan_bit_identical_to_eager_serial_on_all_gpt2_site_shapes() {
    let sizes = scaled_gpt2_sizes();
    let mut planned = session(4, ShardPolicy::Auto, SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    let mut plan_outs: Vec<Vec<f32>> =
        sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for (i, (&size, out)) in sizes.iter().zip(plan_outs.iter_mut()).enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let op = PlanOp::new(size)
            .with_b_layout(InputLayout::Transposed)
            .prefetchable_b(true);
        planned.record_gemm(&mut plan, &op, &a, &b_t, out).unwrap();
    }
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 4000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        session(1, fixed(1), SchedulePolicy::Fifo)
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
            .unwrap();
        assert_eq!(
            reference, plan_outs[i],
            "{size}: recorded output must be bit-identical to eager serial"
        );
    }
    let report = planned.execute(&mut plan).unwrap();
    assert_eq!(report.stats.len(), 12);
    assert!(report.makespan_growth_s <= report.serial_growth_s + 1e-12);
}

/// A depth-1 unsharded FIFO plan replays the paper's strictly serial
/// Figure-7 schedule: identical per-stage modeled totals, timeline, and
/// stage sequence as driving the same stream eagerly.
#[test]
fn depth1_fifo_plan_reproduces_figure7_stage_sequence() {
    let sizes = scaled_gpt2_sizes();

    let mut eager = session(1, fixed(1), SchedulePolicy::Fifo);
    for (i, &size) in sizes.iter().enumerate() {
        let (a, b_t) = random_inputs(size, 5000 + i as u64);
        let mut c = vec![0.0f32; size.m * size.n];
        eager.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
    }

    let mut planned = session(1, fixed(1), SchedulePolicy::Fifo);
    let mut plan = StepPlan::new();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for (i, (&size, out)) in sizes.iter().zip(outs.iter_mut()).enumerate() {
        let (a, b_t) = random_inputs(size, 5000 + i as u64);
        // The Figure-7 chain: each invocation strictly after the previous.
        let mut op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
        if let Some(h) = plan.chain_head() {
            op = op.after(h);
        }
        let n = planned.record_gemm(&mut plan, &op, &a, &b_t, out).unwrap();
        plan.set_chain(n);
    }
    let report = planned.execute(&mut plan).unwrap();
    assert_eq!(report.order, (0..12).collect::<Vec<_>>());
    assert_eq!(report.prefetched, 0);
    for stage in STAGES {
        assert_eq!(
            planned.modeled_stage_s(stage),
            eager.modeled_stage_s(stage),
            "stage '{stage}' must accumulate identically"
        );
    }
    assert_eq!(planned.pipeline.makespan_s(), eager.pipeline.makespan_s());
    assert_eq!(planned.pipeline.serial_s(), eager.pipeline.serial_s());
    assert_eq!(planned.pipeline.hidden_s(), 0.0, "strictly serial: no overlap");
    assert_eq!(planned.invocations, eager.invocations);
}

/// The plan window spans the whole step, so BatchBySize groups same-size
/// ops that an eager ring could never see together (they were separated by
/// wait boundaries).
#[test]
fn whole_step_batching_cuts_reconfigs_across_wait_boundaries() {
    let sizes = scaled_gpt2_sizes();
    let rounds = 2;

    // Eager ring: depth-4 window, BatchBySize — revisited sizes are 12
    // submissions apart, far outside the window.
    let mut eager = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| random_inputs(s, 6000 + i as u64))
        .collect();
    let mut outs: Vec<Vec<f32>> = sizes.iter().map(|s| vec![0.0f32; s.m * s.n]).collect();
    for _ in 0..rounds {
        let mut pending: Vec<(usize, Ticket)> = Vec::new();
        for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            if pending.len() == 4 {
                let (j, t) = pending.remove(0);
                eager.wait(t, &mut outs[j]).unwrap();
            }
            let t = eager
                .submit(&GemmOp::new(size).with_b_layout(InputLayout::Transposed), a, b_t)
                .unwrap();
            pending.push((i, t));
        }
        for (j, t) in pending {
            eager.wait(t, &mut outs[j]).unwrap();
        }
    }
    let eager_reconfig = eager.modeled_stage_s(STAGE_RECONFIG);

    let mut planned = session(4, fixed(1), SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    for _ in 0..rounds {
        for (i, (&size, (a, b_t))) in sizes.iter().zip(&inputs).enumerate() {
            let op = PlanOp::new(size).with_b_layout(InputLayout::Transposed);
            planned
                .record_gemm(&mut plan, &op, a, b_t, &mut outs[i])
                .unwrap();
        }
    }
    let report = planned.execute(&mut plan).unwrap();
    let plan_reconfig = planned.modeled_stage_s(STAGE_RECONFIG);
    assert!(
        plan_reconfig < eager_reconfig,
        "whole-step batching must strictly cut reconfig time: plan {plan_reconfig} \
         vs eager ring {eager_reconfig}"
    );
    assert_eq!(
        report.reconfigs, 12,
        "each distinct size reconfigures once across the whole step"
    );
}

/// Auto-shard selection stays bit-identical on every site shape and its
/// modeled single-invocation schedule is never worse than unsharded.
#[test]
fn auto_sharding_bit_identical_and_no_worse_on_all_gpt2_site_shapes() {
    for (i, &size) in scaled_gpt2_sizes().iter().enumerate() {
        let (a, b_t) = random_inputs(size, 7000 + i as u64);
        let mut reference = vec![0.0f32; size.m * size.n];
        let mut unsharded = session(1, fixed(1), SchedulePolicy::Fifo);
        unsharded
            .gemm(size, &a, &b_t, InputLayout::Transposed, &mut reference)
            .unwrap();
        let mut auto = session(1, ShardPolicy::Auto, SchedulePolicy::Fifo);
        let mut c = vec![0.0f32; size.m * size.n];
        auto.gemm(size, &a, &b_t, InputLayout::Transposed, &mut c).unwrap();
        assert_eq!(reference, c, "{size}: auto sharding must be bit-identical");
        assert!(
            auto.pipeline.makespan_s() <= unsharded.pipeline.makespan_s() + 1e-12,
            "{size}: auto ({} strips) modeled worse than unsharded",
            auto.shards_for(size).unwrap()
        );
    }
}

/// The acceptance chain on a real training step: recording the whole step
/// and scheduling it with prefetch + BatchBySize is modeled no slower than
/// the eager pipelined (depth-2) schedule, which is no slower than the
/// strictly serial (depth-1) schedule — and strictly faster end to end,
/// driven by the backward pairs and the batched reconfigurations. Numerics
/// stay bit-identical throughout.
#[test]
fn step_makespan_monotone_plan_le_eager_pipelined_le_serial() {
    let cfg = ModelConfig::d4();
    let (b, t) = (2usize, 16usize);
    let mut rng = Rng::new(17);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();

    let step_eager = |depth: usize| -> (f32, Vec<f32>, f64, f64) {
        let mut model = Gpt2Model::new(cfg, 321);
        let mut sess = session(depth, fixed(1), SchedulePolicy::Fifo);
        let loss = model
            .forward(&mut MatmulDispatch::Npu(&mut sess), &tokens, Some(&targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut MatmulDispatch::Npu(&mut sess)).unwrap();
        (
            loss,
            model.grads.as_slice().to_vec(),
            sess.pipeline.makespan_s(),
            sess.pipeline.serial_s(),
        )
    };
    let (loss1, grads1, m1, s1) = step_eager(1);
    let (loss2, grads2, m2, s2) = step_eager(2);

    let mut model = Gpt2Model::new(cfg, 321);
    let mut sess = session(2, fixed(1), SchedulePolicy::BatchBySize);
    let mut plan = StepPlan::new();
    let loss_p = {
        let mut d = MatmulDispatch::Plan {
            session: &mut sess,
            plan: &mut plan,
        };
        let l = model
            .forward(&mut d, &tokens, Some(&targets), b, t)
            .unwrap()
            .unwrap();
        model.zero_grad();
        model.backward(&mut d).unwrap();
        l
    };
    let report = sess.execute(&mut plan).unwrap();
    let (m_plan, s_plan) = (sess.pipeline.makespan_s(), sess.pipeline.serial_s());

    // Bit-identity across every schedule.
    assert_eq!(loss1, loss2);
    assert_eq!(loss1, loss_p);
    assert_eq!(grads1, grads2);
    assert_eq!(grads1, model.grads.as_slice());

    // Same modeled work at both eager depths; the batched plan's serial
    // sum can only shrink further (it removes reconfiguration barriers,
    // never stage work).
    assert!((s1 - s2).abs() < 1e-9, "serial sums must match: {s1} vs {s2}");
    assert!(s_plan <= s2 + 1e-9, "batching may only remove work: {s_plan} vs {s2}");
    // ...monotonically better scheduled.
    assert!((m1 - s1).abs() < 1e-12, "depth 1 is the strictly serial schedule");
    assert!(m2 <= m1 + 1e-12, "pipelining can only help: {m2} vs {m1}");
    assert!(
        m_plan < m2,
        "whole-step plan must be strictly faster than the eager pipelined \
         schedule: {m_plan} vs {m2}"
    );
    assert!(report.prefetched > 0, "forward weights must prefetch");
    assert!(report.reconfigs > 0);
    assert!(report.hidden_growth_s() > 0.0);
}
